"""Checkpoint pruning policies and restore-latest correctness.

The satellite contract: kill/restart resume must pick the correct
surviving checkpoint after pruning, with *numeric* (not lexicographic)
step ordering in `latest_checkpoint`/`_prune`, and the prune policy is
pluggable (`keep_last`, `keep_every_n`, callable) end to end through
`StreamEngine.save` and `ServiceConfig`.
"""
import os

import numpy as np
import pytest

import jax.numpy as jnp

from repro.engine import StreamEngine, stack_deltas
from repro.graphs.generators import erdos_renyi
from repro.graphs.types import GraphDelta
from repro.serving import CheckpointPolicy, FingerService, ServiceConfig
from repro.serving.config import ServiceConfigError, TopKSpec
from repro.train.checkpoint import (
    latest_checkpoint,
    load_manifest,
    resolve_prune_policy,
    save_checkpoint,
)


def _steps_on_disk(ckpt_dir):
    return sorted(int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
                  if d.startswith("step_") and "tmp" not in d)


class TestPrunePolicies:
    def test_keep_last_int_and_tuple_agree(self, tmp_path):
        for sub, policy in (("a", 2), ("b", ("keep_last", 2))):
            d = str(tmp_path / sub)
            for step in (1, 2, 3, 4):
                save_checkpoint(d, step, {"x": jnp.zeros(2)},
                                prune_policy=policy)
            assert _steps_on_disk(d) == [3, 4]

    def test_keep_every_n_archives_and_keeps_recovery_window(self, tmp_path):
        d = str(tmp_path)
        for step in range(1, 11):
            save_checkpoint(d, step, {"x": jnp.zeros(2)},
                            prune_policy=("keep_every_n", 5, 2))
        # archive: 5, 10; recovery window: 9, 10
        assert _steps_on_disk(d) == [5, 9, 10]

    def test_callable_policy(self, tmp_path):
        d = str(tmp_path)
        for step in (1, 2, 3, 4, 5):
            save_checkpoint(d, step, {"x": jnp.zeros(2)},
                            prune_policy=lambda steps: [s for s in steps
                                                        if s % 2 == 1])
        assert _steps_on_disk(d) == [1, 3, 5]

    def test_callable_policy_cannot_prune_newest(self, tmp_path):
        """A policy returning nothing still keeps the checkpoint that
        was just written — save must never destroy its own output."""
        d = str(tmp_path)
        for step in (1, 2):
            save_checkpoint(d, step, {"x": jnp.zeros(2)},
                            prune_policy=lambda steps: [])
        assert _steps_on_disk(d) == [2]

    def test_just_written_survives_in_reused_directory(self, tmp_path):
        """A directory left over from an older run with *higher* steps
        must not swallow a new run's first save: the just-written step
        survives pruning even though it is not the numerically newest,
        and becomes latest once the stale steps age out."""
        d = str(tmp_path)
        for step in (4, 5, 6):  # stale previous deployment
            save_checkpoint(d, step, {"x": jnp.zeros(2)}, prune_policy=3)
        save_checkpoint(d, 1, {"x": jnp.ones(2)}, prune_policy=3)
        assert 1 in _steps_on_disk(d)

    def test_legacy_keep_last_kwarg_still_works(self, tmp_path):
        d = str(tmp_path)
        for step in (1, 2, 3):
            save_checkpoint(d, step, {"x": jnp.zeros(2)}, keep_last=1)
        assert _steps_on_disk(d) == [3]

    def test_both_keep_last_and_policy_is_an_error(self, tmp_path):
        with pytest.raises(ValueError, match="not both"):
            save_checkpoint(str(tmp_path), 0, {"x": jnp.zeros(2)},
                            keep_last=1, prune_policy=2)

    def test_malformed_policy_named_error_before_write(self, tmp_path):
        d = str(tmp_path / "nothing_written")
        with pytest.raises(ValueError, match="unknown prune_policy"):
            save_checkpoint(d, 0, {"x": jnp.zeros(2)},
                            prune_policy=("bogus",))
        assert not os.path.isdir(d)

    def test_resolve_rejects_bool_and_nonpositive(self):
        with pytest.raises(ValueError):
            resolve_prune_policy(0)
        with pytest.raises(ValueError):
            resolve_prune_policy(True)
        with pytest.raises(ValueError, match="keep_every_n period"):
            resolve_prune_policy(("keep_every_n", 0, 1))


class TestNumericStepOrdering:
    def test_latest_is_numeric_not_lexicographic(self, tmp_path):
        """step 100000000 overflows the 8-digit zero-pad, so its dirname
        sorts lexicographically *before* step_99999999; numeric parsing
        must still call it the latest."""
        d = str(tmp_path)
        save_checkpoint(d, 99999999, {"x": jnp.zeros(2)}, prune_policy=10)
        save_checkpoint(d, 100000000, {"x": jnp.ones(2)}, prune_policy=10)
        names = sorted(os.listdir(d))
        assert names[0].endswith("100000000")  # lexicographic trap set
        path = latest_checkpoint(d)
        assert load_manifest(path)["step"] == 100000000

    def test_prune_drops_numerically_oldest(self, tmp_path):
        d = str(tmp_path)
        save_checkpoint(d, 99999999, {"x": jnp.zeros(2)}, prune_policy=10)
        save_checkpoint(d, 100000000, {"x": jnp.zeros(2)}, prune_policy=10)
        save_checkpoint(d, 100000001, {"x": jnp.zeros(2)}, prune_policy=2)
        assert _steps_on_disk(d) == [100000000, 100000001]

    def test_mixed_width_dirnames_order_numerically(self, tmp_path):
        """Checkpoints written by an older job with a narrower zero-pad
        must interleave correctly with the current format."""
        d = str(tmp_path)
        save_checkpoint(d, 7, {"x": jnp.zeros(2)}, prune_policy=10)
        os.rename(os.path.join(d, "step_00000007"),
                  os.path.join(d, "step_7"))  # legacy narrow name
        save_checkpoint(d, 100, {"x": jnp.ones(2)}, prune_policy=10)
        path = latest_checkpoint(d)
        assert load_manifest(path)["step"] == 100
        save_checkpoint(d, 101, {"x": jnp.ones(2)}, prune_policy=2)
        assert _steps_on_disk(d) == [100, 101]


class TestRestoreLatestUnderPruning:
    def _serve(self, engine, st, ticks):
        out = []
        for d in ticks:
            scores, st = engine.tick(st, d)
            out.append(np.asarray(scores))
        return out, st

    def _ticks(self, graphs, t, seed=0):
        rng = np.random.default_rng(seed)
        ticks = []
        for _ in range(t):
            ds = []
            for g in graphs:
                n = g.n_nodes
                i, j = sorted(rng.choice(n, 2, replace=False).tolist())
                w_old = float(np.asarray(g.weights)[i, j])
                ds.append(GraphDelta.from_arrays(
                    [i], [j], [0.5 if w_old == 0 else -w_old], [w_old],
                    n_nodes=n, k_pad=4))
            ticks.append(stack_deltas(ds))
        return ticks

    def test_resume_picks_surviving_checkpoint_after_pruning(self, tmp_path):
        """Kill/restart drill: save every tick under keep_last=2, kill,
        restore — the resumed scores must continue from the *numerically
        latest surviving* step, bit-exact with the uninterrupted run."""
        graphs = [erdos_renyi(16, 0.2, seed=s, weighted=True)
                  for s in range(4)]
        ticks = self._ticks(graphs, 6)
        engine = StreamEngine()
        ref, _ = self._serve(engine, StreamEngine.init_states(graphs),
                             ticks)

        st = StreamEngine.init_states(graphs)
        for step, d in enumerate(ticks[:4], start=1):
            _, st = engine.tick(st, d)
            engine.save(str(tmp_path), st, step=step, prune_policy=2)
        assert _steps_on_disk(str(tmp_path)) == [3, 4]  # 1, 2 pruned

        fresh = StreamEngine()  # simulated restart
        st2, step = fresh.restore(str(tmp_path))
        assert step == 4
        for t, d in enumerate(ticks[4:], start=4):
            scores, st2 = fresh.tick(st2, d)
            np.testing.assert_array_equal(np.asarray(scores), ref[t])

    def test_service_periodic_save_respects_config_policy(self, tmp_path):
        """ServiceConfig wiring: checkpoint.every_ticks auto-saves with
        the config's prune policy, and FingerService.restore resumes
        from the latest survivor."""
        graphs = [erdos_renyi(16, 0.2, seed=s, weighted=True)
                  for s in range(4)]
        ticks = self._ticks(graphs, 6, seed=3)
        config = ServiceConfig(
            batch_size=4, n_pad=16, k_pad=4, topk=TopKSpec(k=2),
            checkpoint=CheckpointPolicy(directory=str(tmp_path),
                                        prune=("keep_every_n", 4, 1),
                                        every_ticks=2))
        svc = FingerService.open(config, graphs)
        for d in ticks:
            svc.ingest(d)
            svc.poll()
        final = svc.scores()
        svc.close()
        # auto-saved at 2, 4, 6; keep_every_n=4 keeps 4, newest keeps 6
        assert _steps_on_disk(str(tmp_path)) == [4, 6]

        svc2 = FingerService.restore(config)
        assert svc2.step == 6
        np.testing.assert_array_equal(svc2.scores() is None, True)
        # resumed state serves the next tick identically to the live one
        nxt = self._ticks(graphs, 1, seed=99)[0]
        svc2.ingest(nxt)
        ref_engine = StreamEngine()
        ref_states, _ = ref_engine.restore(str(tmp_path))
        ref_scores, _ = ref_engine.tick(ref_states, nxt)
        np.testing.assert_array_equal(np.asarray(svc2.poll().scores),
                                      np.asarray(ref_scores))
        svc2.close()
        assert np.isfinite(final).all()

    def test_bad_config_policy_fails_at_validate(self):
        with pytest.raises(ServiceConfigError, match="prune policy"):
            ServiceConfig(batch_size=2, n_pad=8, k_pad=2,
                          checkpoint=CheckpointPolicy(
                              directory="/tmp/x", prune=-1)).validate()
        with pytest.raises(ServiceConfigError, match="every_ticks"):
            ServiceConfig(batch_size=2, n_pad=8, k_pad=2,
                          checkpoint=CheckpointPolicy(
                              directory=None,
                              every_ticks=2)).validate()


class TestLayoutGenerationRoundTrip:
    """Satellite contract: checkpoints record their layout generation,
    and `FingerService.restore` walks a checkpoint taken under an older
    layout forward through the directory's migration journal — so one
    checkpoint restores bit-exact onto *both* the generation it was
    saved under and the generation the live service has since migrated
    to (save at n_pad=128, compact() to 96)."""

    B, N0, N_PAD, NEW_N_PAD, K_PAD = 4, 90, 128, 96, 4

    def _tick(self, graphs, seed):
        rng = np.random.default_rng(seed)
        ds = []
        for g in graphs:
            i, j = sorted(rng.choice(self.N0, 2, replace=False).tolist())
            w_old = float(np.asarray(g.weights)[i, j])
            ds.append(GraphDelta.from_arrays(
                [i], [j], [0.5 if w_old == 0 else -w_old], [w_old],
                n_nodes=self.N0, n_pad=self.N_PAD, k_pad=self.K_PAD))
        return ds

    def test_restore_across_compaction_both_generations(self, tmp_path):
        import jax

        from repro.serving import FingerService

        graphs = [erdos_renyi(self.N0, 0.05, seed=s, weighted=True)
                  for s in range(self.B)]
        cfg = ServiceConfig(
            batch_size=self.B, n_pad=self.N_PAD, k_pad=self.K_PAD,
            topk=TopKSpec(k=2),
            checkpoint=CheckpointPolicy(directory=str(tmp_path)))
        svc = FingerService.open(cfg, graphs)
        svc.ingest(self._tick(graphs, seed=1))
        svc.poll()
        svc.save()  # generation 0, n_pad=128
        saved = jax.device_get(svc.states())

        report = svc.compact(new_n_pad=self.NEW_N_PAD)
        assert (report.old_n_pad, report.new_n_pad) == (self.N_PAD,
                                                        self.NEW_N_PAD)
        assert report.generation == 1
        live = jax.device_get(svc.states())

        # (a) onto the OLD generation: the checkpoint's own layout.
        svc_old = FingerService.restore(cfg)
        old = jax.device_get(svc_old.states())
        for a, b in zip(jax.tree_util.tree_leaves(saved),
                        jax.tree_util.tree_leaves(old)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert svc_old.layout.generation == 0
        svc_old.close()

        # (b) onto the NEW generation: walked forward through the
        # journaled compaction, bit-exact with the live migrated state.
        svc_new = FingerService.restore(cfg.with_(n_pad=self.NEW_N_PAD))
        new = jax.device_get(svc_new.states())
        assert svc_new.layout.generation == 1
        for a, b in zip(jax.tree_util.tree_leaves(live),
                        jax.tree_util.tree_leaves(new)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

        # both serve the next tick identically (old-layout deltas are
        # remapped by the restored service's reconstructed grace table)
        nxt = self._tick(graphs, seed=7)
        svc.ingest(nxt)
        svc_new.ingest(nxt)
        np.testing.assert_array_equal(
            np.asarray(svc.poll().scores),
            np.asarray(svc_new.poll().scores))
        svc_new.close()
        svc.close()

    def test_restore_without_migration_chain_is_named_error(self, tmp_path):
        from repro.serving import FingerService

        graphs = [erdos_renyi(8, 0.3, seed=s, weighted=True)
                  for s in range(2)]
        cfg = ServiceConfig(batch_size=2, n_pad=8, k_pad=2,
                            topk=TopKSpec(k=1),
                            checkpoint=CheckpointPolicy(str(tmp_path)))
        with FingerService.open(cfg, graphs) as svc:
            svc.save()
        # no layout log at all -> the pre-existing named error
        with pytest.raises(ServiceConfigError, match="layout log"):
            FingerService.restore(cfg.with_(n_pad=16))

    def test_restore_across_grow_via_journal(self, tmp_path):
        """The grow record (index_map=None) also journals: a checkpoint
        saved pre-repad restores onto the grown layout by padding."""
        import jax

        from repro.serving import FingerService

        graphs = [erdos_renyi(8, 0.3, seed=s, weighted=True)
                  for s in range(2)]
        cfg = ServiceConfig(batch_size=2, n_pad=8, k_pad=2,
                            topk=TopKSpec(k=1),
                            checkpoint=CheckpointPolicy(str(tmp_path)))
        svc = FingerService.open(cfg, graphs)
        svc.save()
        svc.repad(12)
        live = jax.device_get(svc.states())
        svc_new = FingerService.restore(cfg.with_(n_pad=12))
        assert svc_new.layout.generation == 1
        for a, b in zip(jax.tree_util.tree_leaves(live),
                        jax.tree_util.tree_leaves(
                            jax.device_get(svc_new.states()))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        svc_new.close()
        svc.close()
