"""Shared fixtures. NOTE: no XLA_FLAGS here by design — smoke tests and
benches must see 1 device; only the dry-run subprocesses get 512."""
import numpy as np
import pytest

import jax


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True)
def _f32_default():
    # keep tests deterministic across jax versions
    yield
