"""End-to-end behaviour: the paper's three application tasks on the
synthesized datasets + FINGER-telemetry training integration."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.baselines import veo_score
from repro.core import finger_state, jsdist_fast, jsdist_incremental
from repro.graphs.streams import (
    churn_stream,
    dos_attack_sequence,
    hic_bifurcation_sequence,
)


class TestDosDetection:
    """Paper Table 3: the planted DoS transition gets the top JS score."""

    def test_finger_detects_dos(self):
        hits = 0
        trials = 6
        for seed in range(trials):
            seq, attack_at = dos_attack_sequence(n=250, attack_frac=0.05,
                                                 seed=seed)
            scores = [float(jsdist_fast(seq.graphs[t], seq.graphs[t + 1],
                                        power_iters=50))
                      for t in range(len(seq.graphs) - 1)]
            top2 = np.argsort(scores)[-2:]
            hits += int(attack_at in top2)
        assert hits >= trials - 1, f"detected {hits}/{trials}"

    def test_incremental_agrees_with_fast(self):
        seq, attack_at = dos_attack_sequence(n=200, attack_frac=0.08, seed=3)
        st = finger_state(seq.graphs[0])
        inc_scores = []
        for d in seq.deltas:
            dist, st = jsdist_incremental(st, d, exact_smax=True)
            inc_scores.append(float(dist))
        assert int(np.argmax(inc_scores)) == attack_at


class TestBifurcationDetection:
    """Paper Fig. 4: TDS local structure flags the planted bifurcation."""

    def test_finger_tds_peaks_at_bifurcation(self):
        seq = hic_bifurcation_sequence(n=150, bifurcation_at=5, seed=0)
        dists = [float(jsdist_fast(seq.graphs[t], seq.graphs[t + 1],
                                   power_iters=50))
                 for t in range(len(seq.graphs) - 1)]
        # the transition into config B (index 5 -> 6) dominates
        assert int(np.argmax(dists)) == 5

    def test_veo_blind_to_weighted_change(self):
        """The paper's point: VEO is insensitive to edge-weight changes."""
        seq = hic_bifurcation_sequence(n=120, bifurcation_at=5, seed=1)
        veo = [float(veo_score(seq.graphs[t], seq.graphs[t + 1]))
               for t in range(len(seq.graphs) - 1)]
        finger = [float(jsdist_fast(seq.graphs[t], seq.graphs[t + 1],
                                    power_iters=50))
                  for t in range(len(seq.graphs) - 1)]
        # FINGER contrast (peak vs median) far exceeds VEO's
        f_contrast = max(finger) / (np.median(finger) + 1e-12)
        v_contrast = max(veo) / (np.median(veo) + 1e-12)
        assert f_contrast > v_contrast


class TestChurnAnomaly:
    """Wikipedia-style ex-post-facto: JS distance correlates with the
    fraction-of-edges-changed proxy across a bursty churn stream."""

    def test_correlation_with_proxy(self):
        seq = churn_stream(n=150, steps=25, burst_steps=(8, 17),
                           burst_multiplier=12.0, seed=2)
        st = finger_state(seq.graphs[0])
        scores = []
        for d in seq.deltas:
            dist, st = jsdist_incremental(st, d, exact_smax=True)
            scores.append(float(dist))
        proxy = seq.anomaly_truth
        pcc = np.corrcoef(scores, proxy)[0, 1]
        assert pcc > 0.5, f"PCC {pcc}"
        top3 = set(np.argsort(scores)[-3:].tolist())
        assert len({8, 17} & top3) >= 1


@pytest.mark.slow
class TestTrainingIntegration:
    def test_loss_decreases_and_probes_run(self):
        from repro.configs.base import get_config
        from repro.launch.train import run

        cfg = get_config("granite-moe-3b-a800m").reduced()
        _, _, history = run(cfg, steps=25, batch_size=8, seq=64,
                            probe_every=5, lr=3e-3, log=lambda *a: None)
        losses = [h["loss"] for h in history]
        assert losses[-1] < losses[0] - 0.5, (losses[0], losses[-1])
        assert any("attn_entropy_mean" in h for h in history)
        assert any("routing_jsdist" in h for h in history)

    def test_resume_reproduces_training(self, tmp_path):
        from repro.configs.base import get_config
        from repro.launch.train import run

        cfg = get_config("qwen1.5-0.5b").reduced()
        _, _, h_full = run(cfg, steps=12, batch_size=4, seq=32,
                           probe_every=0, log=lambda *a: None)
        ck = str(tmp_path / "ck")
        run(cfg, steps=6, batch_size=4, seq=32, ckpt_dir=ck, ckpt_every=6,
            probe_every=0, log=lambda *a: None)
        _, _, h_resumed = run(cfg, steps=12, batch_size=4, seq=32,
                              ckpt_dir=ck, ckpt_every=100, probe_every=0,
                              log=lambda *a: None)
        assert abs(h_full[-1]["loss"] - h_resumed[-1]["loss"]) < 1e-2
