"""Distributed FINGER (shard_map) == serial, verified in a subprocess
with 8 placeholder devices (the flag must not leak into other tests)."""
import json
import os
import subprocess
import sys

import pytest

# the whole module drives an 8-placeholder-device jax in a subprocess
pytestmark = pytest.mark.slow

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import finger_state, vnge_hat
from repro.distributed.finger_dist import (
    distributed_finger_state,
    distributed_power_iteration,
    shard_edge_list,
)
from repro.graphs import EdgeList
from repro.graphs.generators import erdos_renyi
from repro.graphs.spectral import power_iteration_lmax

mesh = jax.make_mesh((8,), ("data",))
g = erdos_renyi(200, 0.05, seed=3, weighted=True)
el = EdgeList.from_dense(g)
el_sharded = shard_edge_list(el, mesh, "data")

serial = finger_state(g)
dist = distributed_finger_state(el_sharded, mesh, "data")

lam_serial = float(power_iteration_lmax(g, num_iters=200, tol=1e-9))
lam_dist = float(distributed_power_iteration(el_sharded, mesh, "data",
                                             num_iters=200, tol=1e-9))
out = {
    "q_serial": float(serial.q), "q_dist": float(dist.q),
    "smax_serial": float(serial.s_max), "smax_dist": float(dist.s_max),
    "stot_serial": float(serial.s_total), "stot_dist": float(dist.s_total),
    "lam_serial": lam_serial, "lam_dist": lam_dist,
    "n_devices": jax.device_count(),
}
print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def dist_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_runs_on_8_devices(dist_results):
    assert dist_results["n_devices"] == 8


def test_distributed_q_matches_serial(dist_results):
    assert abs(dist_results["q_serial"] - dist_results["q_dist"]) < 1e-5


def test_distributed_smax_stot_match(dist_results):
    assert abs(dist_results["smax_serial"] - dist_results["smax_dist"]) < 1e-4
    r = dist_results
    assert abs(r["stot_serial"] - r["stot_dist"]) / r["stot_serial"] < 1e-6


def test_distributed_power_iteration_matches(dist_results):
    r = dist_results
    assert abs(r["lam_serial"] - r["lam_dist"]) / r["lam_serial"] < 1e-3
