"""Fast serving smoke (non-slow, single host process): a tiny
FingerService in *each* placement mode — multipod via a 1×N host mesh —
runs a few ticks, answers a top-k query, and round-trips save/restore
with identical resumed scores.

This is the CI canary for the declarative serving surface: it exercises
config validation, plan compilation, both ingestion modes, the
checkpoint policy wiring, and the placement-specific top-k paths in a
few seconds on one CPU device.
"""
import numpy as np
import pytest

import jax

from repro.graphs.generators import erdos_renyi
from repro.graphs.types import GraphDelta
from repro.serving import (
    CheckpointPolicy,
    FingerService,
    ServiceConfig,
    TopKSpec,
)

B, N_PAD, K_PAD, TICKS = 8, 16, 3, 4


def _graphs():
    return [erdos_renyi(8 + 2 * (s % 4), 0.25, seed=s, weighted=True)
            for s in range(B)]


def _ticks(seed=0):
    graphs = _graphs()
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(TICKS):
        ds = []
        for g in graphs:
            n = g.n_nodes
            i, j = sorted(rng.choice(n, 2, replace=False).tolist())
            w_old = float(np.asarray(g.weights)[i, j])
            ds.append(GraphDelta.from_arrays(
                [i], [j], [0.5 if w_old == 0 else -w_old], [w_old],
                n_nodes=n, n_pad=N_PAD, k_pad=K_PAD))
        out.append(ds)
    return out


def _mesh_for(placement):
    if placement == "local":
        return None
    if placement == "sharded":
        return jax.make_mesh((jax.device_count(),), ("data",))
    # multipod smoke runs on a 1×N host mesh — the pod axis is size 1,
    # which still exercises the ("pod", "data") shard_map + per-pod
    # top-k code path.
    return jax.make_mesh((1, jax.device_count()), ("pod", "data"))


@pytest.mark.parametrize("placement,ingestion", [
    ("local", "sync"),
    ("local", "double_buffered"),
    ("sharded", "double_buffered"),
    ("multipod", "double_buffered"),
])
def test_placement_smoke_with_save_restore(placement, ingestion,
                                           tmp_path):
    config = ServiceConfig(
        batch_size=B, n_pad=N_PAD, k_pad=K_PAD,
        placement=placement, ingestion=ingestion,
        topk=TopKSpec(k=2),
        checkpoint=CheckpointPolicy(directory=str(tmp_path)))
    ticks = _ticks()

    # uninterrupted reference run
    with FingerService.open(config, _graphs(),
                            mesh=_mesh_for(placement)) as svc:
        ref = []
        for d in ticks:
            svc.ingest(d)
            report = svc.poll()
            assert report is not None
            ref.append(svc.scores())
        vals, ids = svc.top_anomalies(2)
        assert vals.shape == (2,) and ids.shape == (2,)
        assert vals[0] >= vals[1] >= 0.0
        order = np.argsort(ref[-1])[::-1][:2]
        np.testing.assert_array_equal(ids, order)
        if placement == "multipod":
            pv, pi = svc.top_anomalies(2, per_pod=True)
            assert pv.shape == (1, 2)  # 1 pod on the host mesh
            np.testing.assert_array_equal(pi[0], order)

    # save mid-run, then restore into a fresh service and resume
    with FingerService.open(config, _graphs(),
                            mesh=_mesh_for(placement)) as svc:
        for d in ticks[:2]:
            svc.ingest(d)
            svc.poll()
        svc.save()
        assert svc.step == 2

    resumed = FingerService.restore(config, mesh=_mesh_for(placement))
    assert resumed.step == 2
    for t, d in enumerate(ticks[2:], start=2):
        resumed.ingest(d)
        resumed.poll()
        np.testing.assert_array_equal(resumed.scores(), ref[t])
    resumed.close()
