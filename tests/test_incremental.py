"""Theorem 2 incremental updates: exactness vs batch recomputation,
streams, and hypothesis properties over random deltas."""
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core import (
    finger_state,
    jsdist_incremental,
    jsdist_stream,
    jsdist_tilde,
    update_state,
)
from repro.graphs import DenseGraph, GraphDelta, apply_delta_dense
from repro.graphs.generators import erdos_renyi
from repro.graphs.streams import churn_stream


def _random_delta(g, rng, k=20, delete_frac=0.4):
    n = g.n_nodes
    w = np.asarray(g.weights)
    pairs = {}
    for _ in range(k):
        i, j = rng.integers(0, n, 2)
        if i == j:
            continue
        i, j = min(i, j), max(i, j)
        w_old = w[i, j]
        if w_old > 0 and rng.random() < delete_frac:
            dw = -w_old
        else:
            dw = float(rng.uniform(0.1, 2.0))
        pairs[(i, j)] = (dw, w_old)
    ii = np.array([p[0] for p in pairs], np.int32)
    jj = np.array([p[1] for p in pairs], np.int32)
    dw = np.array([v[0] for v in pairs.values()], np.float32)
    wo = np.array([v[1] for v in pairs.values()], np.float32)
    return GraphDelta.from_arrays(ii, jj, dw, wo, n_nodes=n)


class TestTheorem2:
    @pytest.mark.parametrize("seed", range(5))
    def test_incremental_q_exact(self, seed):
        rng = np.random.default_rng(seed)
        g = erdos_renyi(80, 0.1, seed=seed, weighted=True)
        st_ = finger_state(g)
        delta = _random_delta(g, rng)
        new = update_state(st_, delta, exact_smax=True)
        ref = finger_state(apply_delta_dense(g, delta))
        assert abs(float(new.q) - float(ref.q)) < 2e-5
        assert abs(float(new.s_total) - float(ref.s_total)) < 1e-3
        assert abs(float(new.s_max) - float(ref.s_max)) < 1e-4
        np.testing.assert_allclose(np.asarray(new.strengths),
                                   np.asarray(ref.strengths), atol=1e-4)

    def test_paper_smax_never_decreases(self):
        """eq. (3)'s Δs_max is clamped at 0 (paper-faithful mode)."""
        rng = np.random.default_rng(1)
        g = erdos_renyi(50, 0.2, seed=1, weighted=True)
        st_ = finger_state(g)
        delta = _random_delta(g, rng, k=40, delete_frac=1.0)
        new = update_state(st_, delta, exact_smax=False)
        assert float(new.s_max) >= float(st_.s_max) - 1e-6

    def test_chained_updates_stay_exact(self):
        rng = np.random.default_rng(2)
        g = erdos_renyi(60, 0.15, seed=2, weighted=True)
        st_ = finger_state(g)
        for _ in range(10):
            delta = _random_delta(g, rng)
            st_ = update_state(st_, delta, exact_smax=True)
            g = apply_delta_dense(g, delta)
        ref = finger_state(g)
        assert abs(float(st_.q) - float(ref.q)) < 1e-4


class TestStreams:
    def test_stream_scan_matches_loop(self):
        seq = churn_stream(n=100, steps=8, seed=4, k_pad=256)
        st0 = finger_state(seq.graphs[0])
        # python loop
        st_ = st0
        loop_d = []
        for d in seq.deltas:
            dist, st_ = jsdist_incremental(st_, d)
            loop_d.append(float(dist))
        # single lax.scan over the stacked deltas
        stacked = GraphDelta(
            senders=jnp.stack([d.senders for d in seq.deltas]),
            receivers=jnp.stack([d.receivers for d in seq.deltas]),
            dw=jnp.stack([d.dw for d in seq.deltas]),
            w_old=jnp.stack([d.w_old for d in seq.deltas]),
            mask=jnp.stack([d.mask for d in seq.deltas]),
            n_nodes=seq.graphs[0].n_nodes,
        )
        scan_d, _ = jsdist_stream(st0, stacked)
        np.testing.assert_allclose(np.asarray(scan_d), np.asarray(loop_d),
                                   rtol=1e-3, atol=1e-5)

    def test_incremental_close_to_batch_tilde(self):
        seq = churn_stream(n=100, steps=5, seed=5, k_pad=256)
        st_ = finger_state(seq.graphs[0])
        for t, d in enumerate(seq.deltas):
            dist, st_ = jsdist_incremental(st_, d, exact_smax=True)
            ref = float(jsdist_tilde(seq.graphs[t], seq.graphs[t + 1]))
            assert abs(float(dist) - ref) < 5e-3


class TestRegressions:
    def test_self_loops_dropped_with_warning(self):
        """i == j slots would double-count strengths and violate
        Lemma 1's zero-diagonal assumption — they must be dropped."""
        import warnings

        from repro.graphs import EdgeList

        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            el = EdgeList.from_arrays([0, 1, 2], [0, 2, 2],
                                      [1.0, 2.0, 3.0], n_nodes=4)
            d = GraphDelta.from_arrays([3, 0], [3, 1], [1.0, 1.0],
                                       [0.0, 0.0], n_nodes=4)
        assert any("self-loop" in str(w.message) for w in rec)
        assert float(jnp.sum(el.mask)) == 1.0  # only (1, 2) survives
        assert float(jnp.sum(d.mask)) == 1.0   # only (0, 1) survives
        np.testing.assert_allclose(np.asarray(el.strengths()),
                                   [0.0, 2.0, 2.0, 0.0])

    def test_empty_graph_entropy_is_zero(self):
        """trace(L) = 0 used to yield H̃ = -ln(1e-30) ≈ 69 nats."""
        from repro.core import vnge_hat, vnge_tilde

        g = DenseGraph.from_weights(jnp.zeros((12, 12)))
        assert float(vnge_tilde(g)) == 0.0
        assert float(vnge_hat(g)) == 0.0
        assert float(finger_state(g).h_tilde()) == 0.0
        # jit-safe: no host branch on traced values
        assert float(jax.jit(vnge_tilde)(g)) == 0.0

    @pytest.mark.parametrize("seed", range(8))
    @pytest.mark.parametrize("method", ["dense", "compact"])
    def test_delta_to_empty_graph(self, seed, method):
        """Deleting every edge snaps to the canonical empty state (Q=1,
        H̃=0) instead of nan-poisoning Q or exploding H̃ on float
        cancellation residue (seed-dependent before the fix)."""
        g = erdos_renyi(30, 0.3, seed=seed, weighted=True)
        w = np.asarray(g.weights)
        iu, ju = np.triu_indices(30, k=1)
        nz = w[iu, ju] > 0
        d = GraphDelta.from_arrays(iu[nz], ju[nz], -w[iu, ju][nz],
                                   w[iu, ju][nz], n_nodes=30)
        st_ = update_state(finger_state(g), d, exact_smax=True,
                           method=method)
        assert float(st_.s_total) == 0.0
        assert float(st_.q) == 1.0
        assert float(st_.s_max) == 0.0
        assert float(st_.h_tilde()) == 0.0

    def test_shrink_to_one_edge_is_not_empty(self):
        """A delta deleting all but one small edge must NOT snap to the
        empty state — the survivor graph's statistics stay exact."""
        n = 40
        w = np.zeros((n, n), np.float32)
        iu, ju = np.triu_indices(n, k=1)
        w[iu, ju] = 100.0  # heavy graph: S ≈ 1.56e5
        w = w + w.T
        g = DenseGraph.from_weights(jnp.asarray(w))
        keep = (0, 1)
        dw = np.full(len(iu), -100.0, np.float32)
        wo = np.full(len(iu), 100.0, np.float32)
        ki = np.where((iu == keep[0]) & (ju == keep[1]))[0][0]
        dw[ki] = -99.5  # survivor edge keeps weight 0.5
        for method in ("dense", "compact"):
            d = GraphDelta.from_arrays(iu, ju, dw, wo, n_nodes=n)
            st_ = update_state(finger_state(g), d, exact_smax=True,
                               method=method)
            ref = finger_state(apply_delta_dense(g, d))
            assert float(st_.s_total) > 0.5  # not snapped to empty
            assert abs(float(st_.s_total) - float(ref.s_total)) < 0.5
            assert abs(float(st_.h_tilde()) - float(ref.h_tilde())) < 1e-3

    def test_revive_from_empty_graph(self):
        """Adding edges to an empty state reproduces the from-scratch
        state exactly (c' = 1/ΔS path, beyond the paper's S > 0)."""
        empty = finger_state(DenseGraph.from_weights(jnp.zeros((12, 12))))
        d = GraphDelta.from_arrays([0, 1, 5], [1, 2, 9],
                                   [1.5, 0.5, 2.0], [0.0, 0.0, 0.0],
                                   n_nodes=12)
        for method in ("dense", "compact"):
            st_ = update_state(empty, d, exact_smax=True, method=method)
            ref = finger_state(apply_delta_dense(
                DenseGraph.from_weights(jnp.zeros((12, 12))), d))
            assert abs(float(st_.q) - float(ref.q)) < 1e-6
            assert abs(float(st_.h_tilde()) - float(ref.h_tilde())) < 1e-6

    def test_empty_then_continue_stream_stays_finite(self):
        """A stream that empties and refills keeps emitting finite
        scores (was nan-forever)."""
        g = erdos_renyi(25, 0.3, seed=2, weighted=True)
        st_ = finger_state(g)
        w = np.asarray(g.weights)
        iu, ju = np.triu_indices(25, k=1)
        nz = w[iu, ju] > 0
        kill = GraphDelta.from_arrays(iu[nz], ju[nz], -w[iu, ju][nz],
                                      w[iu, ju][nz], n_nodes=25)
        refill = GraphDelta.from_arrays([0, 3], [1, 4], [1.0, 2.0],
                                        [0.0, 0.0], n_nodes=25)
        d1, st_ = jsdist_incremental(st_, kill, exact_smax=True)
        d2, st_ = jsdist_incremental(st_, refill, exact_smax=True)
        assert np.isfinite(float(d1)) and np.isfinite(float(d2))
        assert np.isfinite(float(st_.q))

    def test_stream_synthesizers_shape_stable(self):
        """dos/hic sequences emit one common padded delta shape, so a
        jitted incremental step compiles exactly once."""
        from repro.graphs.streams import (
            dos_attack_sequence,
            hic_bifurcation_sequence,
        )

        seq, _ = dos_attack_sequence(n=100, n_graphs=5, seed=0)
        assert len({d.dw.shape for d in seq.deltas}) == 1
        seq2 = hic_bifurcation_sequence(n=50, n_samples=5,
                                        bifurcation_at=2, seed=0)
        assert len({d.dw.shape for d in seq2.deltas}) == 1
        # and the common shape survives an explicit k_pad
        seq3, _ = dos_attack_sequence(n=100, n_graphs=4, seed=1,
                                      k_pad=64)
        assert {d.dw.shape for d in seq3.deltas} == {(64,)}


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), k=st.integers(1, 30))
def test_property_incremental_matches_batch(seed, k):
    rng = np.random.default_rng(seed)
    g = erdos_renyi(40, 0.2, seed=seed, weighted=True)
    st_ = finger_state(g)
    delta = _random_delta(g, rng, k=k)
    new = update_state(st_, delta, exact_smax=True)
    ref = finger_state(apply_delta_dense(g, delta))
    assert abs(float(new.q) - float(ref.q)) < 5e-5
