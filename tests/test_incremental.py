"""Theorem 2 incremental updates: exactness vs batch recomputation,
streams, and hypothesis properties over random deltas."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import (
    finger_state,
    jsdist_incremental,
    jsdist_stream,
    jsdist_tilde,
    update_state,
)
from repro.graphs import DenseGraph, GraphDelta, apply_delta_dense
from repro.graphs.generators import erdos_renyi
from repro.graphs.streams import churn_stream


def _random_delta(g, rng, k=20, delete_frac=0.4):
    n = g.n_nodes
    w = np.asarray(g.weights)
    pairs = {}
    for _ in range(k):
        i, j = rng.integers(0, n, 2)
        if i == j:
            continue
        i, j = min(i, j), max(i, j)
        w_old = w[i, j]
        if w_old > 0 and rng.random() < delete_frac:
            dw = -w_old
        else:
            dw = float(rng.uniform(0.1, 2.0))
        pairs[(i, j)] = (dw, w_old)
    ii = np.array([p[0] for p in pairs], np.int32)
    jj = np.array([p[1] for p in pairs], np.int32)
    dw = np.array([v[0] for v in pairs.values()], np.float32)
    wo = np.array([v[1] for v in pairs.values()], np.float32)
    return GraphDelta.from_arrays(ii, jj, dw, wo, n_nodes=n)


class TestTheorem2:
    @pytest.mark.parametrize("seed", range(5))
    def test_incremental_q_exact(self, seed):
        rng = np.random.default_rng(seed)
        g = erdos_renyi(80, 0.1, seed=seed, weighted=True)
        st_ = finger_state(g)
        delta = _random_delta(g, rng)
        new = update_state(st_, delta, exact_smax=True)
        ref = finger_state(apply_delta_dense(g, delta))
        assert abs(float(new.q) - float(ref.q)) < 2e-5
        assert abs(float(new.s_total) - float(ref.s_total)) < 1e-3
        assert abs(float(new.s_max) - float(ref.s_max)) < 1e-4
        np.testing.assert_allclose(np.asarray(new.strengths),
                                   np.asarray(ref.strengths), atol=1e-4)

    def test_paper_smax_never_decreases(self):
        """eq. (3)'s Δs_max is clamped at 0 (paper-faithful mode)."""
        rng = np.random.default_rng(1)
        g = erdos_renyi(50, 0.2, seed=1, weighted=True)
        st_ = finger_state(g)
        delta = _random_delta(g, rng, k=40, delete_frac=1.0)
        new = update_state(st_, delta, exact_smax=False)
        assert float(new.s_max) >= float(st_.s_max) - 1e-6

    def test_chained_updates_stay_exact(self):
        rng = np.random.default_rng(2)
        g = erdos_renyi(60, 0.15, seed=2, weighted=True)
        st_ = finger_state(g)
        for _ in range(10):
            delta = _random_delta(g, rng)
            st_ = update_state(st_, delta, exact_smax=True)
            g = apply_delta_dense(g, delta)
        ref = finger_state(g)
        assert abs(float(st_.q) - float(ref.q)) < 1e-4


class TestStreams:
    def test_stream_scan_matches_loop(self):
        seq = churn_stream(n=100, steps=8, seed=4, k_pad=256)
        st0 = finger_state(seq.graphs[0])
        # python loop
        st_ = st0
        loop_d = []
        for d in seq.deltas:
            dist, st_ = jsdist_incremental(st_, d)
            loop_d.append(float(dist))
        # single lax.scan over the stacked deltas
        stacked = GraphDelta(
            senders=jnp.stack([d.senders for d in seq.deltas]),
            receivers=jnp.stack([d.receivers for d in seq.deltas]),
            dw=jnp.stack([d.dw for d in seq.deltas]),
            w_old=jnp.stack([d.w_old for d in seq.deltas]),
            mask=jnp.stack([d.mask for d in seq.deltas]),
            n_nodes=seq.graphs[0].n_nodes,
        )
        scan_d, _ = jsdist_stream(st0, stacked)
        np.testing.assert_allclose(np.asarray(scan_d), np.asarray(loop_d),
                                   rtol=1e-3, atol=1e-5)

    def test_incremental_close_to_batch_tilde(self):
        seq = churn_stream(n=100, steps=5, seed=5, k_pad=256)
        st_ = finger_state(seq.graphs[0])
        for t, d in enumerate(seq.deltas):
            dist, st_ = jsdist_incremental(st_, d, exact_smax=True)
            ref = float(jsdist_tilde(seq.graphs[t], seq.graphs[t + 1]))
            assert abs(float(dist) - ref) < 5e-3


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), k=st.integers(1, 30))
def test_property_incremental_matches_batch(seed, k):
    rng = np.random.default_rng(seed)
    g = erdos_renyi(40, 0.2, seed=seed, weighted=True)
    st_ = finger_state(g)
    delta = _random_delta(g, rng, k=k)
    new = update_state(st_, delta, exact_smax=True)
    ref = finger_state(apply_delta_dense(g, delta))
    assert abs(float(new.q) - float(ref.q)) < 5e-5
