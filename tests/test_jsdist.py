"""Jensen–Shannon graph distance: Algorithms 1 & 2 and metric properties."""
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import (
    average_graph,
    finger_state,
    jsdist_exact,
    jsdist_fast,
    jsdist_incremental,
    jsdist_tilde,
)
from repro.graphs import DenseGraph
from repro.graphs.generators import erdos_renyi


class TestMetricProperties:
    def test_identity(self):
        g = erdos_renyi(60, 0.1, seed=0)
        assert float(jsdist_fast(g, g)) < 1e-4
        assert float(jsdist_exact(g, g)) < 1e-3

    def test_symmetry(self):
        g1 = erdos_renyi(60, 0.1, seed=1)
        g2 = erdos_renyi(60, 0.1, seed=2)
        for fn in (jsdist_fast, jsdist_exact, jsdist_tilde):
            assert abs(float(fn(g1, g2)) - float(fn(g2, g1))) < 1e-5

    def test_nonnegative(self):
        for s in range(4):
            g1 = erdos_renyi(40, 0.15, seed=s, weighted=True)
            g2 = erdos_renyi(40, 0.15, seed=s + 100, weighted=True)
            assert float(jsdist_fast(g1, g2)) >= 0.0

    def test_triangle_inequality_exact(self):
        """JSdist (exact) is a metric (Endres & Schindelin 2003)."""
        gs = [erdos_renyi(30, 0.2, seed=s, weighted=True) for s in range(3)]
        d01 = float(jsdist_exact(gs[0], gs[1]))
        d12 = float(jsdist_exact(gs[1], gs[2]))
        d02 = float(jsdist_exact(gs[0], gs[2]))
        assert d02 <= d01 + d12 + 1e-5


class TestAlgorithms:
    def test_average_graph(self):
        g1 = erdos_renyi(40, 0.2, seed=0, weighted=True)
        g2 = erdos_renyi(40, 0.2, seed=1, weighted=True)
        gbar = average_graph(g1, g2)
        np.testing.assert_allclose(
            np.asarray(gbar.weights),
            0.5 * (np.asarray(g1.weights) + np.asarray(g2.weights)),
            rtol=1e-6)

    def test_fast_approximates_exact(self):
        """Algorithm 1 tracks the exact JS distance (same ordering of
        near/far pairs)."""
        base = erdos_renyi(100, 0.1, seed=5)
        near = erdos_renyi(100, 0.1, seed=5)  # identical
        w = np.asarray(base.weights).copy()
        w[:30, :30] = 0  # large perturbation
        far = DenseGraph.from_weights(jnp.asarray(w))
        d_near = float(jsdist_fast(base, near))
        d_far = float(jsdist_fast(base, far))
        assert d_near < d_far

    def test_incremental_matches_batch_tilde(self):
        from repro.graphs.streams import churn_stream

        seq = churn_stream(n=80, steps=4, seed=6, k_pad=128)
        st_ = finger_state(seq.graphs[0])
        for t, d in enumerate(seq.deltas):
            dist, st_ = jsdist_incremental(st_, d, exact_smax=True)
            ref = float(jsdist_tilde(seq.graphs[t], seq.graphs[t + 1]))
            assert abs(float(dist) - ref) < 5e-3


class TestAverageGraphMaskParity:
    """The EdgeList and DenseGraph branches of `average_graph` must
    agree on mask-aware layouts: union node set, each operand's weights
    gated by its *own* mask (a slot one endpoint holds inactive must
    contribute zero even when the other endpoint activates it)."""

    def _mixed_mask_pair(self):
        # g1: active {0,1,2} of 4; slot 3 carries stale weight residue.
        w1 = np.zeros((4, 4), np.float32)
        w1[0, 1] = w1[1, 0] = 1.0
        w1[2, 3] = w1[3, 2] = 5.0  # touches g1-inactive node 3
        m1 = jnp.asarray([1.0, 1.0, 1.0, 0.0])
        # g2: active {0,1,3}; edge (1,3) into g1's inactive slot.
        w2 = np.zeros((4, 4), np.float32)
        w2[1, 3] = w2[3, 1] = 2.0
        m2 = jnp.asarray([1.0, 1.0, 0.0, 1.0])
        return w1, m1, w2, m2

    def test_edgelist_matches_dense_on_mixed_masks(self):
        from repro.graphs import EdgeList

        w1, m1, w2, m2 = self._mixed_mask_pair()
        gd1 = DenseGraph(weights=jnp.asarray(w1), n_nodes=4, node_mask=m1)
        gd2 = DenseGraph(weights=jnp.asarray(w2), n_nodes=4, node_mask=m2)
        ge1 = EdgeList.from_arrays([0, 2], [1, 3], [1.0, 5.0], n_nodes=4,
                                   node_mask=m1)
        ge2 = EdgeList.from_arrays([1], [3], [2.0], n_nodes=4,
                                   node_mask=m2)
        bar_d = average_graph(gd1, gd2)
        bar_e = average_graph(ge1, ge2)
        np.testing.assert_allclose(np.asarray(bar_d.weights),
                                   np.asarray(bar_e.weights),
                                   rtol=0, atol=0)
        np.testing.assert_array_equal(np.asarray(bar_d.node_mask),
                                      np.asarray(bar_e.node_mask))
        # union node set: every node live in either endpoint is in Ḡ
        np.testing.assert_array_equal(np.asarray(bar_d.node_mask),
                                      [1.0, 1.0, 1.0, 1.0])

    def test_own_mask_gates_before_union(self):
        """g1's stale (2,3) weight (node 3 inactive in g1) must not
        reach Ḡ just because g2 activates node 3."""
        w1, m1, w2, m2 = self._mixed_mask_pair()
        gd1 = DenseGraph(weights=jnp.asarray(w1), n_nodes=4, node_mask=m1)
        gd2 = DenseGraph(weights=jnp.asarray(w2), n_nodes=4, node_mask=m2)
        bar = average_graph(gd1, gd2)
        assert float(bar.weights[2, 3]) == 0.0
        assert float(bar.weights[1, 3]) == 1.0  # g2's live edge, halved
        assert float(bar.weights[0, 1]) == 0.5

    def test_jsdist_consistent_across_representations(self):
        from repro.graphs import EdgeList

        g1 = erdos_renyi(24, 0.2, seed=3, weighted=True).pad_to(32)
        g2 = erdos_renyi(30, 0.2, seed=4, weighted=True).pad_to(32)
        e1 = EdgeList.from_dense(g1, m_pad=256)
        e2 = EdgeList.from_dense(g2, m_pad=256)
        d_dense = float(jsdist_tilde(g1, g2))
        d_edges = float(jsdist_tilde(e1, e2))
        assert abs(d_dense - d_edges) < 1e-6


@settings(max_examples=15, deadline=None)
@given(s1=st.integers(0, 1000), s2=st.integers(0, 1000))
def test_property_symmetry_nonneg(s1, s2):
    g1 = erdos_renyi(30, 0.2, seed=s1)
    g2 = erdos_renyi(30, 0.2, seed=s2)
    if float(jnp.sum(g1.weights)) == 0 or float(jnp.sum(g2.weights)) == 0:
        return
    d12 = float(jsdist_fast(g1, g2, power_iters=50))
    d21 = float(jsdist_fast(g2, g1, power_iters=50))
    assert d12 >= 0
    assert abs(d12 - d21) < 1e-4
