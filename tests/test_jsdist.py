"""Jensen–Shannon graph distance: Algorithms 1 & 2 and metric properties."""
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import (
    average_graph,
    finger_state,
    jsdist_exact,
    jsdist_fast,
    jsdist_incremental,
    jsdist_tilde,
)
from repro.graphs import DenseGraph
from repro.graphs.generators import erdos_renyi


class TestMetricProperties:
    def test_identity(self):
        g = erdos_renyi(60, 0.1, seed=0)
        assert float(jsdist_fast(g, g)) < 1e-4
        assert float(jsdist_exact(g, g)) < 1e-3

    def test_symmetry(self):
        g1 = erdos_renyi(60, 0.1, seed=1)
        g2 = erdos_renyi(60, 0.1, seed=2)
        for fn in (jsdist_fast, jsdist_exact, jsdist_tilde):
            assert abs(float(fn(g1, g2)) - float(fn(g2, g1))) < 1e-5

    def test_nonnegative(self):
        for s in range(4):
            g1 = erdos_renyi(40, 0.15, seed=s, weighted=True)
            g2 = erdos_renyi(40, 0.15, seed=s + 100, weighted=True)
            assert float(jsdist_fast(g1, g2)) >= 0.0

    def test_triangle_inequality_exact(self):
        """JSdist (exact) is a metric (Endres & Schindelin 2003)."""
        gs = [erdos_renyi(30, 0.2, seed=s, weighted=True) for s in range(3)]
        d01 = float(jsdist_exact(gs[0], gs[1]))
        d12 = float(jsdist_exact(gs[1], gs[2]))
        d02 = float(jsdist_exact(gs[0], gs[2]))
        assert d02 <= d01 + d12 + 1e-5


class TestAlgorithms:
    def test_average_graph(self):
        g1 = erdos_renyi(40, 0.2, seed=0, weighted=True)
        g2 = erdos_renyi(40, 0.2, seed=1, weighted=True)
        gbar = average_graph(g1, g2)
        np.testing.assert_allclose(
            np.asarray(gbar.weights),
            0.5 * (np.asarray(g1.weights) + np.asarray(g2.weights)),
            rtol=1e-6)

    def test_fast_approximates_exact(self):
        """Algorithm 1 tracks the exact JS distance (same ordering of
        near/far pairs)."""
        base = erdos_renyi(100, 0.1, seed=5)
        near = erdos_renyi(100, 0.1, seed=5)  # identical
        w = np.asarray(base.weights).copy()
        w[:30, :30] = 0  # large perturbation
        far = DenseGraph.from_weights(jnp.asarray(w))
        d_near = float(jsdist_fast(base, near))
        d_far = float(jsdist_fast(base, far))
        assert d_near < d_far

    def test_incremental_matches_batch_tilde(self):
        from repro.graphs.streams import churn_stream

        seq = churn_stream(n=80, steps=4, seed=6, k_pad=128)
        st_ = finger_state(seq.graphs[0])
        for t, d in enumerate(seq.deltas):
            dist, st_ = jsdist_incremental(st_, d, exact_smax=True)
            ref = float(jsdist_tilde(seq.graphs[t], seq.graphs[t + 1]))
            assert abs(float(dist) - ref) < 5e-3


@settings(max_examples=15, deadline=None)
@given(s1=st.integers(0, 1000), s2=st.integers(0, 1000))
def test_property_symmetry_nonneg(s1, s2):
    g1 = erdos_renyi(30, 0.2, seed=s1)
    g2 = erdos_renyi(30, 0.2, seed=s2)
    if float(jnp.sum(g1.weights)) == 0 or float(jnp.sum(g2.weights)) == 0:
        return
    d12 = float(jsdist_fast(g1, g2, power_iters=50))
    d21 = float(jsdist_fast(g2, g1, power_iters=50))
    assert d12 >= 0
    assert abs(d12 - d21) < 1e-4
