"""The loop-aware HLO cost model: exactness on known-FLOP programs."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import analyze, parse_hlo


def _flops_of(f, *specs):
    compiled = jax.jit(f).lower(*specs).compile()
    return analyze(compiled.as_text())


def test_single_matmul_exact():
    s = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    r = _flops_of(lambda a, b: a @ b, s, s)
    assert abs(r["flops"] - 2 * 256 ** 3) / (2 * 256 ** 3) < 1e-6


def test_scan_multiplies_by_trip_count():
    s = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def f(x, w):
        return jax.lax.scan(lambda c, _: (c @ w, None), x, None, length=7)[0]

    r = _flops_of(f, s, s)
    expect = 7 * 2 * 128 ** 3
    assert abs(r["flops"] - expect) / expect < 1e-6


def test_nested_scans_multiply():
    s = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def f(x, w):
        def outer(c, _):
            inner = jax.lax.scan(lambda c2, _: (c2 @ w, None), c, None,
                                 length=4)[0]
            return inner, None
        return jax.lax.scan(outer, x, None, length=3)[0]

    r = _flops_of(f, s, s)
    expect = 12 * 2 * 128 ** 3
    assert abs(r["flops"] - expect) / expect < 1e-6


def test_xla_cost_analysis_undercounts_loops():
    """Documents WHY hlo_analysis exists: XLA counts scan bodies once."""
    s = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def f(x, w):
        return jax.lax.scan(lambda c, _: (c @ w, None), x, None, length=9)[0]

    compiled = jax.jit(f).lower(s, s).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    xla_flops = float(ca.get("flops", 0.0))
    assert xla_flops < 2 * 2 * 128 ** 3  # body counted once, not 9x


def test_traffic_nonzero_and_scales_with_loop():
    s = jax.ShapeDtypeStruct((256, 256), jnp.float32)

    def f1(x, w):
        return jax.lax.scan(lambda c, _: (c @ w, None), x, None, length=2)[0]

    def f2(x, w):
        return jax.lax.scan(lambda c, _: (c @ w, None), x, None, length=8)[0]

    b1 = _flops_of(f1, s, s)["bytes"]
    b2 = _flops_of(f2, s, s)["bytes"]
    assert b2 > 2.5 * b1


@pytest.mark.slow
def test_collectives_counted():
    import subprocess, sys, os, json
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.hlo_analysis import analyze
mesh = jax.make_mesh((4,), ("d",))
sh = NamedSharding(mesh, P("d", None))
def f(x):
    y = x @ x.T          # needs all-gather of the sharded operand
    return jnp.sum(y)
spec = jax.ShapeDtypeStruct((256, 256), jnp.float32)
with mesh:
    c = jax.jit(f, in_shardings=(sh,)).lower(spec).compile()
r = analyze(c.as_text())
print(json.dumps({"coll": r["collective_bytes"]}))
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stderr[-1500:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["coll"] > 0
