"""Per-architecture smoke tests: reduced config, one forward/train step
on CPU, output shapes + no NaNs; decode==prefill consistency."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.archs import ARCH_IDS
from repro.configs.base import get_config
from repro.distributed.sharding import NO_SHARDING
from repro.models.api import (
    build_decode_fn,
    build_forward_fn,
    build_loss_fn,
    cache_spec,
    init_cache_arrays,
    model_param_defs,
)
from repro.models.params import count_params, init_params
from repro.optim.adamw import AdamWConfig, init_state
from repro.train.step import build_train_step

RULES = NO_SHARDING


def _batch_for(cfg, b, s):
    toks = jax.random.randint(jax.random.PRNGKey(7), (b, s), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    if cfg.is_encoder_decoder:
        batch["frames"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(8), (b, cfg.encoder_seq, cfg.d_model))
    elif cfg.frontend == "vision_stub":
        batch["extra_embeds"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(8), (b, cfg.n_frontend_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def test_forward_and_train_step(self, arch):
        cfg = get_config(arch).reduced()
        defs = model_param_defs(cfg, RULES)
        params = init_params(defs, jax.random.PRNGKey(0))
        b, s = 2, 64
        batch = _batch_for(cfg, b, s)

        loss = float(build_loss_fn(cfg, RULES)(params, batch))
        assert np.isfinite(loss), f"{arch}: NaN loss"
        assert loss > 0

        opt_state = init_state(params)
        step = jax.jit(build_train_step(cfg, RULES, AdamWConfig(lr_peak=1e-3)))
        params2, opt_state2, metrics = step(params, opt_state, batch)
        assert np.isfinite(float(metrics["loss"]))
        assert np.isfinite(float(metrics["grad_norm"]))
        # at least one param changed
        changed = any(
            not np.allclose(np.asarray(a), np.asarray(b_))
            for a, b_ in zip(jax.tree_util.tree_leaves(params),
                             jax.tree_util.tree_leaves(params2)))
        assert changed, f"{arch}: optimizer step was a no-op"

    def test_logits_shape(self, arch):
        cfg = get_config(arch).reduced()
        params = init_params(model_param_defs(cfg, RULES),
                             jax.random.PRNGKey(0))
        b, s = 2, 32
        batch = _batch_for(cfg, b, s)
        logits = build_forward_fn(cfg, RULES)(params, batch)
        assert logits.shape[0] == b
        assert logits.shape[-1] >= cfg.vocab_size  # padded vocab allowed
        assert np.all(np.isfinite(np.asarray(logits, np.float32)[..., :cfg.vocab_size]))

    def test_decode_step(self, arch):
        cfg = get_config(arch).reduced()
        params = init_params(model_param_defs(cfg, RULES),
                             jax.random.PRNGKey(0))
        b = 2
        cache = init_cache_arrays(cfg, b, 32, RULES)
        dec = build_decode_fn(cfg, RULES)
        logits, cache2 = dec(params, jnp.zeros((b, 1), jnp.int32), cache,
                             jnp.asarray(0, jnp.int32))
        assert logits.shape[:2] == (b, 1)
        assert np.all(np.isfinite(
            np.asarray(logits, np.float32)[..., :cfg.vocab_size]))


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "gemma2-27b",
                                  "h2o-danube-1.8b", "mamba2-130m",
                                  "jamba-1.5-large-398b",
                                  "granite-moe-3b-a800m"])
def test_decode_matches_prefill(arch):
    """Step-by-step decode (f32 cache) reproduces teacher-forced logits."""
    cfg = get_config(arch).reduced()
    defs = model_param_defs(cfg, RULES)
    params = init_params(defs, jax.random.PRNGKey(1))
    b, s = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0,
                              cfg.vocab_size)
    full = np.asarray(build_forward_fn(cfg, RULES)(params, {"tokens": toks}),
                      np.float32)
    structs, _ = cache_spec(cfg, b, s, RULES)
    cache = jax.tree_util.tree_map(
        lambda sd: jnp.zeros(sd.shape, jnp.float32), structs)
    dec = build_decode_fn(cfg, RULES)
    outs = []
    for t in range(s):
        lg, cache = dec(params, toks[:, t:t + 1], cache,
                        jnp.asarray(t, jnp.int32))
        outs.append(np.asarray(lg[:, 0], np.float32))
    dec_logits = np.stack(outs, 1)
    np.testing.assert_allclose(dec_logits[..., :cfg.vocab_size],
                               full[..., :cfg.vocab_size],
                               rtol=2e-3, atol=2e-3)


def test_param_counts_match_published_scale():
    """Full configs land near their nameplate sizes (sanity on configs)."""
    expect = {
        "gemma2-27b": (25e9, 30e9),
        "qwen1.5-0.5b": (0.4e9, 0.65e9),
        "h2o-danube-1.8b": (1.5e9, 2.1e9),
        "internlm2-20b": (17e9, 23e9),
        "llama4-maverick-400b-a17b": (350e9, 450e9),
        "jamba-1.5-large-398b": (330e9, 440e9),
        "mamba2-130m": (0.1e9, 0.18e9),
        "whisper-small": (0.2e9, 0.3e9),
    }
    for arch, (lo, hi) in expect.items():
        cfg = get_config(arch)
        n = count_params(model_param_defs(cfg, RULES))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B params out of range"
