"""repro.fleet: the multi-tenant serving fleet (ISSUE 8).

Acceptance anchors:
- bucketed routing, live cross-shard promotion, in-flight compaction
  and shard-kill recovery all preserve every tenant's JSdist scores to
  1e-5 against a single oracle `FingerService` fed the same deltas —
  including a tenant whose shard compacts *between* ingest and poll
  (stamped old-generation deltas in flight);
- whole-fleet `save`/`restore` round-trips (per-shard serving
  checkpoints + the ``fleet.json`` manifest), and post-save recovery
  rebuilds tenants from the on-disk checkpoints;
- every public fleet error is importable by name from `repro.fleet`
  (discovery-guarded, mirroring the kernels parity guard).
"""
import pathlib
import re

import numpy as np
import pytest

from repro.fleet import (
    AdmissionError,
    FingerFleet,
    FleetConfig,
    FleetConfigError,
    FleetError,
    FleetIngestError,
    FleetLifecycleError,
    PoolGroupError,
    PoolSpec,
    RecoveryError,
    ShardUnavailableError,
    UnknownTenantError,
)
from repro.graphs.generators import erdos_renyi
from repro.graphs.types import GraphDelta
from repro.serving import FingerService, ServiceConfig, TopKSpec
from repro.serving.migrate import embed_delta

K_PAD, J_PAD = 3, 2


def _two_bucket_cfg(method="dense", **kw):
    return FleetConfig(pools=(
        PoolSpec(name="small", n_pad=8, shards=2, streams_per_shard=2,
                 k_pad=K_PAD, j_pad=J_PAD, method=method),
        PoolSpec(name="large", n_pad=32, shards=2, streams_per_shard=2,
                 k_pad=K_PAD, j_pad=J_PAD, method=method),
    ), **kw)


class Oracle:
    """A single `FingerService` fed every tenant's deltas, embedded
    into one shared layout — the fleet must match it to 1e-5 no matter
    how it shuffles tenants between shards underneath."""

    def __init__(self, names, graphs, n_pad=32):
        self.names = list(names)
        self.n_pad = n_pad
        self.svc = FingerService.open(
            ServiceConfig(batch_size=len(self.names), n_pad=n_pad,
                          k_pad=K_PAD, j_pad=J_PAD,
                          topk=TopKSpec(k=len(self.names))),
            [graphs[n] for n in self.names])
        z = np.zeros((0,), np.float32)
        self.empty = GraphDelta.from_arrays(
            z, z, z, z, n_nodes=0, n_pad=n_pad, k_pad=K_PAD,
            j_pad=J_PAD)

    def tick(self, ds):
        self.svc.ingest([embed_delta(ds[n], self.n_pad) if n in ds
                         else self.empty for n in self.names])
        self.svc.poll()
        vals = np.asarray(self.svc.scores()).ravel()
        return {n: float(vals[i]) for i, n in enumerate(self.names)}

    def close(self):
        self.svc.close()


def _graph(n, seed):
    return erdos_renyi(n, 0.4, seed=seed, weighted=True)


def _delta(n_nodes, seed, scale=2.0):
    r = np.random.default_rng(seed)
    i, j = sorted(r.choice(n_nodes, 2, replace=False).tolist())
    return GraphDelta.from_arrays(
        [i], [j], [float(r.uniform(0.5, scale))], [0.0],
        n_nodes=n_nodes, k_pad=K_PAD, j_pad=J_PAD)


def _assert_parity(got, ref, label, names=None):
    for n in (names or ref):
        assert abs(got[n] - ref[n]) < 1e-5, (label, n, got[n], ref[n])


class TestFleetConfig:
    def test_named_validation_errors(self):
        small = PoolSpec(name="s", n_pad=8, k_pad=2)
        with pytest.raises(FleetConfigError, match="at least one"):
            FleetConfig(pools=()).validate()
        with pytest.raises(FleetConfigError, match="unique"):
            FleetConfig(pools=(small, small)).validate()
        with pytest.raises(FleetConfigError, match="ascending"):
            FleetConfig(pools=(
                PoolSpec(name="a", n_pad=8, k_pad=2),
                PoolSpec(name="b", n_pad=8, k_pad=2))).validate()
        with pytest.raises(FleetConfigError, match="shards"):
            FleetConfig(pools=(
                PoolSpec(name="a", n_pad=8, shards=0,
                         k_pad=2),)).validate()
        # bad shard-level field fails through the serving layer's own
        # diagnostics, renamed to the fleet's config error
        with pytest.raises(FleetConfigError, match="'a'"):
            FleetConfig(pools=(
                PoolSpec(name="a", n_pad=8, k_pad=0),)).validate()
        with pytest.raises(FleetConfigError, match="compact_occupancy"):
            FleetConfig(pools=(small,),
                        compact_occupancy=0.0).validate()
        with pytest.raises(FleetConfigError, match="save_every"):
            FleetConfig(pools=(small,),
                        save_every_ticks=5).validate()
        # sparse pools persist too (SlotMaps serialize into the shard
        # checkpoint manifest) — a sparse + directory config is legal
        FleetConfig(pools=(
            PoolSpec(name="sp", n_pad=64, k_pad=2, j_pad=2,
                     method="sparse_tick", n_slots=8, m_pad=16),),
            directory="/tmp/never").validate()
        with pytest.raises(FleetConfigError, match="no pool named"):
            FleetConfig(pools=(small,)).pool_index("nope")
        assert _two_bucket_cfg().pool_index("large") == 1


class TestErrorExportDiscovery:
    """Every ``*Error`` class defined anywhere under `repro.fleet` must
    be importable by name from the package root (mirrors the kernels
    parity-discovery guard): a new fleet failure mode can never ship
    as an anonymous exception."""

    def test_every_fleet_error_is_exported(self):
        import repro.fleet as pkg

        root = pathlib.Path(list(pkg.__path__)[0])
        found = set()
        for py in root.glob("*.py"):
            found |= set(re.findall(r"^class (\w*Error)\b",
                                    py.read_text(), re.M))
        assert found, "discovery found no fleet error classes"
        for name in sorted(found):
            assert name in pkg.__all__, f"{name} missing from __all__"
            exc = getattr(pkg, name)
            assert issubclass(exc, FleetError), name
            assert issubclass(exc, Exception), name


class TestRoutingOracleParity:
    """The headline invariant: best-fit admission, within-bucket
    growth and cross-bucket auto-promotion are all invisible in the
    scores — every tick matches the single-service oracle to 1e-5."""

    def test_admission_growth_and_promotion_parity(self):
        names = ["a", "b", "c"]
        sizes = {"a": 5, "b": 7, "c": 20}
        graphs = {n: _graph(sizes[n], i + 1)
                  for i, n in enumerate(names)}
        fleet = FingerFleet.open(_two_bucket_cfg())
        oracle = Oracle(names, graphs)
        try:
            for n in names:
                fleet.admit(n, graphs[n])
            # best-fit bucket, least-loaded shard, smallest slot
            at = {n: (e.pool, e.shard, e.slot)
                  for n, e in ((n, fleet.directory.get(n))
                               for n in names)}
            assert at == {"a": (0, 0, 0), "b": (0, 1, 0),
                          "c": (1, 0, 0)}

            def tick(ds):
                fleet.ingest(ds)
                fleet.poll()
                got = fleet.scores()
                _assert_parity(got, oracle.tick(ds),
                               f"step {fleet.step}")
                return got

            for t in range(3):
                tick({n: _delta(sizes[n], 50 + 10 * t + k)
                      for k, n in enumerate(names)})

            # within-bucket growth: joins extend the tenant node space
            # but still fit the small bucket (positions 0..7)
            tick({"a": GraphDelta.from_arrays(
                [0], [6], [1.5], [0.0], n_nodes=7, k_pad=K_PAD,
                j_pad=J_PAD, join=[5, 6])})
            sizes["a"] = 7

            # outgrow the bucket: the capacity pre-pass promotes the
            # tenant to the large pool mid-stream, and the very tick
            # that triggered it still matches the oracle
            tick({"a": GraphDelta.from_arrays(
                [0], [8], [2.0], [0.0], n_nodes=9, k_pad=K_PAD,
                j_pad=J_PAD, join=[7, 8])})
            sizes["a"] = 9
            e = fleet.directory.get("a")
            assert e.pool == 1 and e.slot_of_node.shape[0] == 9

            for t in range(2):
                got = tick({n: _delta(sizes[n], 90 + 10 * t + k)
                            for k, n in enumerate(names)})

            # fleet top-k merge agrees with the oracle's ranking
            merged = fleet.top_anomalies(k=3)
            order = sorted(got, key=lambda n: -got[n])
            assert [n for n, _ in merged] == order
            for n, v in merged:
                assert abs(v - got[n]) < 1e-6

            # evict frees the slot for the next admission
            fleet.evict("b")
            assert "b" not in fleet.directory
            fleet.admit("b2", _graph(6, 77))
            assert fleet.directory.get("b2").pool == 0
        finally:
            fleet.close()
            oracle.close()


class TestAdmissionAndLifecycleErrors:
    def test_named_errors(self):
        cfg = FleetConfig(pools=(
            PoolSpec(name="tiny", n_pad=8, shards=1,
                     streams_per_shard=2, k_pad=K_PAD, j_pad=J_PAD),))
        with FingerFleet.open(cfg) as fleet:
            fleet.admit("a", _graph(4, 1))
            with pytest.raises(AdmissionError, match="already"):
                fleet.admit("a", _graph(4, 1))
            with pytest.raises(AdmissionError, match="node slot"):
                fleet.admit("big", _graph(9, 2))  # no bucket fits
            fleet.admit("b", _graph(4, 3))
            with pytest.raises(AdmissionError):  # every slot taken
                fleet.admit("c", _graph(4, 4))
            with pytest.raises(UnknownTenantError, match="ghost"):
                fleet.ingest({"ghost": _delta(4, 5)})
            # edges touching a node the tenant never joined
            with pytest.raises(FleetIngestError, match="never joined"):
                fleet.ingest({"a": GraphDelta.from_arrays(
                    [0], [6], [1.0], [0.0], n_nodes=7, k_pad=K_PAD,
                    j_pad=J_PAD)})
            with pytest.raises(ShardUnavailableError):
                fleet.shard_service(0, 5)
            # strict ingest/poll alternation
            fleet.ingest({"a": _delta(4, 6)})
            with pytest.raises(FleetLifecycleError, match="staged"):
                fleet.ingest({"a": _delta(4, 7)})
            with pytest.raises(FleetLifecycleError, match="staged"):
                fleet.promote("a")
            fleet.poll()
            with pytest.raises(AdmissionError):
                fleet.promote("a")  # no bigger bucket exists
        with pytest.raises(FleetLifecycleError, match="closed"):
            fleet.scores()


class TestInFlightCompaction:
    """A staged fleet tick survives its shard compacting underneath it:
    the queued deltas are stamped with the pre-compaction generation
    and remapped through the serving grace machinery, and the
    post-compaction scores still match the oracle."""

    def test_staged_tick_survives_compaction(self):
        cfg = FleetConfig(pools=(
            PoolSpec(name="only", n_pad=16, shards=1,
                     streams_per_shard=2, k_pad=K_PAD, j_pad=J_PAD),),
            compact_occupancy=0.95)
        names = ["x", "y"]
        sizes = {"x": 4, "y": 3}
        graphs = {n: _graph(sizes[n], i + 11)
                  for i, n in enumerate(names)}
        fleet = FingerFleet.open(cfg)
        oracle = Oracle(names, graphs, n_pad=16)
        try:
            for n in names:
                fleet.admit(n, graphs[n])
            for t in range(2):
                ds = {n: _delta(sizes[n], 300 + 10 * t + k)
                      for k, n in enumerate(names)}
                fleet.ingest(ds)
                fleet.poll()
                _assert_parity(fleet.scores(), oracle.tick(ds),
                               f"warm step {t}")

            # stage a tick, then compact the shard before polling it
            ds = {n: _delta(sizes[n], 400 + k)
                  for k, n in enumerate(names)}
            fleet.ingest(ds)
            actions = fleet.rebalance()
            assert [a["action"] for a in actions] == ["compact"]
            assert actions[0]["new_n_pad"] < 16
            fleet.poll()
            _assert_parity(fleet.scores(), oracle.tick(ds),
                           "tick across compaction")

            # the composed position maps keep routing correct, and a
            # later join repads the shard back up warm
            ds = {"x": GraphDelta.from_arrays(
                [0], [5], [1.2], [0.0], n_nodes=6, k_pad=K_PAD,
                j_pad=J_PAD, join=[4, 5])}
            fleet.ingest(ds)
            fleet.poll()
            svc = fleet.shard_service(0, 0)
            assert svc.layout.n_pad == 16  # repadded to pool bound
            _assert_parity(fleet.scores(), oracle.tick(ds),
                           "post-compaction join")
        finally:
            fleet.close()
            oracle.close()


class TestRecovery:
    """Shard death: WAL-only ticks while dead, then recovery rebuilds
    the tenant (base ⊕ replay) on a survivor — scores stay on the
    oracle trajectory throughout."""

    def test_kill_wal_recover_parity(self):
        names = ["a", "b", "c"]
        sizes = {"a": 5, "b": 7, "c": 20}
        graphs = {n: _graph(sizes[n], i + 21)
                  for i, n in enumerate(names)}
        fleet = FingerFleet.open(_two_bucket_cfg())
        oracle = Oracle(names, graphs)
        try:
            for n in names:
                fleet.admit(n, graphs[n])

            def tick(ds, live):
                fleet.ingest(ds)
                fleet.poll()
                got, ref = fleet.scores(), oracle.tick(ds)
                _assert_parity(got, ref, f"step {fleet.step}", live)
                return got, ref

            for t in range(2):
                tick({n: _delta(sizes[n], 500 + 10 * t + k)
                      for k, n in enumerate(names)}, names)

            dead = fleet.kill_shard("small", 0)  # tenant "a"
            assert dead.pool == 0 and fleet.live_shards()[0] == [1]
            with pytest.raises(ShardUnavailableError, match="dead"):
                fleet.shard_service(0, 0)
            stale = fleet.scores()["a"]

            # while dead: a's delta is WAL-only; others keep serving
            ds = {n: _delta(sizes[n], 600 + k)
                  for k, n in enumerate(names)}
            _, ref = tick(ds, ["b", "c"])
            assert fleet.scores()["a"] == stale  # last known score

            reports = fleet.recover()
            assert [r["tenant"] for r in reports] == ["a"]
            e = fleet.directory.get("a")
            assert (e.pool, e.shard) == (0, 1)  # surviving small shard
            # the replayed WAL tick lands exactly on the oracle score
            assert abs(fleet.scores()["a"] - ref["a"]) < 1e-5

            tick({n: _delta(sizes[n], 700 + k)
                  for k, n in enumerate(names)}, names)
        finally:
            fleet.close()
            oracle.close()

    def test_recovery_without_base_or_checkpoint_is_named(self):
        cfg = FleetConfig(pools=(
            PoolSpec(name="tiny", n_pad=8, shards=2,
                     streams_per_shard=2, k_pad=K_PAD, j_pad=J_PAD),))
        with FingerFleet.open(cfg) as fleet:
            fleet.admit("a", _graph(4, 1))
            fleet.directory.get("a").base_state = None  # simulate
            fleet.kill_shard("tiny", 0)
            with pytest.raises(RecoveryError, match="checkpoint"):
                fleet.recover()


class TestFleetPersistence:
    """Whole-fleet save/restore plus post-save recovery, which must go
    through the on-disk shard checkpoints (save truncates the
    in-memory bases)."""

    def test_save_restore_kill_recover_roundtrip(self, tmp_path):
        names = ["a", "b", "c"]
        sizes = {"a": 5, "b": 7, "c": 20}
        graphs = {n: _graph(sizes[n], i + 31)
                  for i, n in enumerate(names)}
        cfg = _two_bucket_cfg(directory=str(tmp_path))
        fleet = FingerFleet.open(cfg)
        oracle = Oracle(names, graphs)
        try:
            for n in names:
                fleet.admit(n, graphs[n])

            def tick(f, ds, live=names):
                f.ingest(ds)
                f.poll()
                got, ref = f.scores(), oracle.tick(ds)
                _assert_parity(got, ref, f"step {f.step}", live)
                return got, ref

            # scale=5: keep per-tick JSdists well off zero, so the
            # (float32) host-replay drift after the disk-based
            # recovery below is not sqrt-amplified past the bound
            def ds_at(seed):
                return {n: _delta(sizes[n], seed + k, scale=5.0)
                        for k, n in enumerate(names)}

            for t in range(2):
                tick(fleet, ds_at(800 + 10 * t))
            last = fleet.scores()
            path = fleet.save()
            assert path.endswith("fleet.json")
            assert all(e.base_state is None for e in fleet.directory)
            fleet.close()

            fleet = FingerFleet.restore(cfg)
            assert fleet.step == 2
            got = fleet.scores()  # last known, from the manifest
            _assert_parity(got, last, "restored scores")
            tick(fleet, ds_at(900))

            # post-save recovery: the restored entries carry no
            # in-memory base, so the dead shard's tenants rebuild from
            # its serving checkpoint + their post-restore WAL
            fleet.kill_shard("small", 0)
            _, ref = tick(fleet, ds_at(950), ["b", "c"])
            fleet.recover()
            assert abs(fleet.scores()["a"] - ref["a"]) < 1e-5
            tick(fleet, ds_at(990))
        finally:
            fleet.close()
            oracle.close()

    def test_save_preconditions_are_named(self, tmp_path):
        with FingerFleet.open(_two_bucket_cfg()) as fleet:
            with pytest.raises(FleetConfigError, match="directory"):
                fleet.save()
        cfg = _two_bucket_cfg(directory=str(tmp_path))
        with FingerFleet.open(cfg) as fleet:
            fleet.kill_shard("small", 1)
            with pytest.raises(FleetLifecycleError, match="recover"):
                fleet.save()
        with pytest.raises(FleetConfigError, match="manifest"):
            FingerFleet.restore(_two_bucket_cfg(
                directory=str(tmp_path / "empty")))


class TestSparsePool:
    """A sparse (slot-space) bucket serves virtual-id deltas at parity
    with a dense oracle, and a sparse tenant promotes *live* into a
    dense bucket (slot-map gather) without leaving the oracle
    trajectory."""

    N_VIRT = 64

    def test_sparse_bucket_parity(self):
        cfg = FleetConfig(pools=(
            PoolSpec(name="slots", n_pad=self.N_VIRT, shards=1,
                     streams_per_shard=2, k_pad=4, j_pad=2,
                     method="sparse_tick", n_slots=12, m_pad=24),
            PoolSpec(name="wide", n_pad=128, shards=1,
                     streams_per_shard=2, k_pad=4, j_pad=2),))
        names = ["u", "v"]
        graphs = {n: _graph(8, i + 41) for i, n in enumerate(names)}
        fleet = FingerFleet.open(cfg)
        oracle = FingerService.open(
            ServiceConfig(batch_size=2, n_pad=self.N_VIRT, k_pad=4,
                          j_pad=2, topk=TopKSpec(k=2)),
            [graphs[n] for n in names])
        try:
            for n in names:
                fleet.admit(n, graphs[n])
            assert fleet.directory.get("u").pool == 0  # best fit
            rng = np.random.default_rng(5)

            def tick(t):
                ds = {}
                for n in names:
                    i, j = sorted(rng.choice(8, 2,
                                             replace=False).tolist())
                    ds[n] = GraphDelta.from_arrays(
                        [i], [j], [float(rng.uniform(0.5, 2.0))],
                        [0.0], n_nodes=self.N_VIRT, k_pad=4, j_pad=2)
                fleet.ingest(ds)
                fleet.poll()
                oracle.ingest([ds[n] for n in names])
                oracle.poll()
                got = fleet.scores()
                ref = np.asarray(oracle.scores()).ravel()
                for i, n in enumerate(names):
                    assert abs(got[n] - float(ref[i])) < 1e-5, \
                        (t, n, got[n], float(ref[i]))

            for t in range(3):
                tick(t)
            # live sparse -> dense promotion: the tenant's FINGER row
            # leaves the slot universe through its SlotMap gather and
            # keeps serving from the dense bucket at exact parity
            report = fleet.promote("u")
            e = fleet.directory.get("u")
            assert e.pool == 1 and report["to"][0] == 1
            assert e.slot_of_node is not None
            for t in range(2):
                tick(10 + t)
        finally:
            fleet.close()
            oracle.close()


class TestStackedSequentialParity:
    """The stacked pool-tick dispatch is a pure execution-plane
    optimization: the identical lifecycle — admit → ticks → cross-
    bucket promotion → staged-tick compaction → save/restore → shard
    kill + WAL tick + recovery — run with ``stacked_ticks`` on and off
    produces the same per-tenant scores to 1e-5 at every step. Holds
    for every tick method: the vmapped dense bodies AND the megakernel
    methods, whose stacked spelling is one (S, B)-gridded
    `pallas_call` per layout group."""

    NAMES = ["a", "b", "c"]
    SIZES = {"a": 5, "b": 6, "c": 18}

    def _lifecycle(self, stacked, tmp_path, method="dense"):
        sizes = dict(self.SIZES)
        graphs = {n: _graph(sizes[n], i + 61)
                  for i, n in enumerate(self.NAMES)}
        cfg = _two_bucket_cfg(method=method,
                              compact_occupancy=0.95,
                              stacked_ticks=stacked,
                              directory=str(tmp_path))
        trace = []
        fleet = FingerFleet.open(cfg)
        try:
            for n in self.NAMES:
                fleet.admit(n, graphs[n])

            def tick(seed):
                ds = {n: _delta(sizes[n], seed + k)
                      for k, n in enumerate(self.NAMES)}
                fleet.ingest(ds)
                fleet.poll()
                trace.append(fleet.scores())

            for t in range(3):
                tick(40 + 10 * t)
            fleet.promote("a")  # small -> large, live
            tick(80)
            # compact the vacated small shard under a staged tick
            fleet.ingest({n: _delta(sizes[n], 90 + k)
                          for k, n in enumerate(self.NAMES)})
            actions = fleet.rebalance()
            assert any(a["action"] == "compact" for a in actions)
            fleet.poll()
            trace.append(fleet.scores())
            # save / restore mid-stream, then keep serving
            fleet.save()
            fleet.close()
            fleet = FingerFleet.restore(cfg)
            tick(100)
            # kill b's shard: its tick goes WAL-only, then recovery
            # replays it on the survivor (from the saved checkpoint —
            # the restored entries carry no in-memory base)
            fleet.kill_shard("small", fleet.directory.get("b").shard)
            tick(110)
            fleet.recover()
            trace.append(fleet.scores())
            tick(120)
            trace.append(dict(fleet.top_anomalies(k=3)))
        finally:
            fleet.close()
        return trace

    @staticmethod
    def _assert_traces_match(stacked, sequential):
        assert len(stacked) == len(sequential)
        for i, (s, q) in enumerate(zip(stacked, sequential)):
            assert set(s) == set(q), i
            for n in s:
                assert abs(s[n] - q[n]) < 1e-5, (i, n, s[n], q[n])

    def test_lifecycle_scores_match_to_1e5(self, tmp_path):
        self._assert_traces_match(
            self._lifecycle(True, tmp_path / "on"),
            self._lifecycle(False, tmp_path / "off"))

    def test_fused_lifecycle_scores_match_to_1e5(self, tmp_path):
        """Megakernel pools through the same full lifecycle: the
        stacked (S, B)-gridded launch must be score-invisible against
        per-shard sequential fused ticks — including across the group
        splits promotion and compaction cause."""
        self._assert_traces_match(
            self._lifecycle(True, tmp_path / "on",
                            method="fused_tick"),
            self._lifecycle(False, tmp_path / "off",
                            method="fused_tick"))

    def _sparse_lifecycle(self, stacked, tmp_path):
        """Sparse lifecycle: sparse-pool ticks, live sparse → dense
        promotion, whole-fleet save/restore (SlotMaps through the
        checkpoint manifest), sparse shard kill + WAL tick + disk-
        base recovery."""
        cfg = FleetConfig(pools=(
            PoolSpec(name="slots", n_pad=24, shards=2,
                     streams_per_shard=2, k_pad=4, j_pad=2,
                     method="sparse_tick", n_slots=12, m_pad=24),
            PoolSpec(name="big", n_pad=64, shards=1,
                     streams_per_shard=2, k_pad=4, j_pad=2),
        ), stacked_ticks=stacked, directory=str(tmp_path))
        names = ["u", "v", "w"]
        graphs = {n: _graph(8, i + 71) for i, n in enumerate(names)}
        trace = []
        rng = np.random.default_rng(13)
        fleet = FingerFleet.open(cfg)
        try:
            for n in names:
                fleet.admit(n, graphs[n])
            assert all(fleet.directory.get(n).pool == 0
                       for n in names)

            def tick():
                ds = {}
                for n in names:
                    i, j = sorted(rng.choice(8, 2,
                                             replace=False).tolist())
                    ds[n] = GraphDelta.from_arrays(
                        [i], [j], [float(rng.uniform(0.5, 2.0))],
                        [0.0], n_nodes=24, k_pad=4, j_pad=2)
                fleet.ingest(ds)
                fleet.poll()
                trace.append(fleet.scores())

            for _ in range(3):
                tick()
            fleet.promote("u")  # sparse -> dense, live
            assert fleet.directory.get("u").pool == 1
            tick()
            # sparse shards persist: whole-fleet save/restore
            fleet.save()
            fleet.close()
            fleet = FingerFleet.restore(cfg)
            tick()
            # kill one sparse shard (its stacked group shrinks S=2→1),
            # WAL-only tick, then disk-base recovery through the
            # checkpoint's serialized SlotMaps
            fleet.kill_shard("slots", fleet.directory.get("v").shard)
            tick()
            fleet.recover()
            trace.append(fleet.scores())
            tick()
        finally:
            fleet.close()
        return trace

    def test_sparse_lifecycle_scores_match_to_1e5(self, tmp_path):
        self._assert_traces_match(
            self._sparse_lifecycle(True, tmp_path / "on"),
            self._sparse_lifecycle(False, tmp_path / "off"))


class TestPoolTickGrouping:
    """`pooltick` group rules: one stacked launch covers one layout
    group of one method — mixed-method entry lists are a caller bug
    and raise by name instead of warming a plan no poll() will use."""

    def test_warm_pool_tick_rejects_mixed_methods(self):
        from repro.fleet import pooltick
        from repro.graphs.layout import NodeLayout

        dense = ServiceConfig(batch_size=2, n_pad=8, k_pad=3, j_pad=2)
        fused = dense.with_(method="fused_tick")
        lay = NodeLayout(8)
        with pytest.raises(PoolGroupError, match="mixed"):
            pooltick.warm_pool_tick([(dense, lay), (fused, lay)])


class TestWalRetention:
    """`FleetConfig.wal_retention_ticks`: ingest prunes WAL entries
    older than the window, `wal_floor` records the pruned horizon, and
    recovery refuses a gapped log by name."""

    def _cfg(self, **kw):
        return FleetConfig(pools=(
            PoolSpec(name="tiny", n_pad=8, shards=2,
                     streams_per_shard=2, k_pad=K_PAD, j_pad=J_PAD),),
            wal_retention_ticks=2, **kw)

    def test_config_rejects_nonpositive_retention(self):
        with pytest.raises(FleetConfigError, match="wal_retention"):
            FleetConfig(pools=(
                PoolSpec(name="tiny", n_pad=8, k_pad=2),),
                wal_retention_ticks=0).validate()

    def test_prunes_and_raises_on_gapped_recovery(self):
        with FingerFleet.open(self._cfg()) as fleet:
            fleet.admit("a", _graph(4, 1))
            for t in range(5):
                fleet.ingest({"a": _delta(4, 100 + t)})
                fleet.poll()
            e = fleet.directory.get("a")
            assert [s for s, _ in e.wal] == [4, 5]
            assert e.wal_floor == 3
            # steps (0, 3] are gone and no durable base covers them
            fleet.kill_shard("tiny", e.shard)
            with pytest.raises(RecoveryError,
                               match="wal_retention_ticks"):
                fleet.recover()

    def test_save_keeps_recovery_within_window(self, tmp_path):
        with FingerFleet.open(
                self._cfg(directory=str(tmp_path))) as fleet:
            fleet.admit("a", _graph(4, 1))
            for t in range(3):
                fleet.ingest({"a": _delta(4, 200 + t)})
                fleet.poll()
            fleet.save()  # durable base at step 3 covers the pruning
            for t in range(2):
                fleet.ingest({"a": _delta(4, 300 + t)})
                fleet.poll()
            e = fleet.directory.get("a")
            assert e.base_step == 3 and e.wal_floor == 3
            before = fleet.scores()["a"]
            fleet.kill_shard("tiny", e.shard)
            fleet.recover()  # disk base + intact WAL: no gap
            assert abs(fleet.scores()["a"] - before) < 1e-5


class TestFleetHotPathBudgets:
    """The PR 9 dispatch/transfer regression gate, via the extended
    sentinel: warm fleet ticks run at zero compiles, `poll()` issues
    one launch per pool layout-group (not per shard), `ingest()` and
    the poll dispatch pull nothing to host, and `scores()` costs at
    most one device→host transfer per pool per tick."""

    def test_fleet_chain_budgets(self):
        from repro.analysis.sentinel import run_fleet_chain

        r = run_fleet_chain(ticks_per_phase=2)
        assert r["ok"]
        assert r["phases"] == {"ticks_promotion": 0,
                               "ticks_staged_compaction": 0}
        assert r["launches_steady"] == len(r["pools"])
        assert r["launches_post_compaction"] > len(r["pools"])
        assert r["transfer_budget_scores_per_tick"] == len(r["pools"])


class TestFleetProperty:
    """The ISSUE's end-to-end property: a randomized tick stream over
    ≥2 buckets × ≥2 shards in which a tenant is promoted across
    buckets mid-stream, a shard compacts under a staged tick, a shard
    is killed and its tenants restored onto survivors — and every
    tenant's score matches the single-service oracle to 1e-5 at every
    step."""

    def test_fleet_matches_oracle_through_all_events(self):
        names = ["a", "b", "c"]
        sizes = {"a": 5, "b": 6, "c": 18}
        graphs = {n: _graph(sizes[n], i + 61)
                  for i, n in enumerate(names)}
        cfg = _two_bucket_cfg(compact_occupancy=0.95)
        fleet = FingerFleet.open(cfg)
        oracle = Oracle(names, graphs)
        rng = np.random.default_rng(7)
        try:
            for n in names:
                fleet.admit(n, graphs[n])
            fleet.warm(background=True).wait(timeout=600)

            def rand_ds(grow=None):
                ds = {}
                for n in names:
                    if n == grow:
                        new = sizes[n] + 2
                        ds[n] = GraphDelta.from_arrays(
                            [0], [new - 1],
                            [float(rng.uniform(0.5, 2.0))], [0.0],
                            n_nodes=new, k_pad=K_PAD, j_pad=J_PAD,
                            join=[new - 2, new - 1])
                        sizes[n] = new
                    else:
                        ds[n] = _delta(sizes[n], int(rng.integers(1e6)))
                return ds

            for step in range(12):
                live = list(names)
                # a grows by 2 nodes on steps 2/4/6 — it crosses the
                # small bucket's n_pad=8 bound mid-stream and the
                # capacity pre-pass promotes it to the large pool
                ds = rand_ds(grow="a" if step in (2, 4, 6) else None)
                fleet.ingest(ds)
                if step == 5:
                    # compact under the staged tick (occupancy of the
                    # vacated small shards is now below 0.95)
                    fleet.rebalance()
                fleet.poll()
                got, ref = fleet.scores(), oracle.tick(ds)
                if step >= 8 and self._dead_holds(fleet, "b"):
                    live.remove("b")
                _assert_parity(got, ref, f"property step {step}", live)
                if step == 7:
                    fleet.kill_shard(
                        "small",
                        fleet.directory.get("b").shard)
                if step == 9:
                    fleet.recover()
                    assert abs(fleet.scores()["b"] - ref["b"]) < 1e-5
            assert fleet.directory.get("a").pool == 1
        finally:
            fleet.close()
            oracle.close()

    @staticmethod
    def _dead_holds(fleet, name):
        e = fleet.directory.get(name)
        return fleet._is_dead(e.pool, e.shard)
