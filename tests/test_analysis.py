"""repro.analysis: the static-analysis gate (ISSUE 6).

Acceptance anchors:
- each lint rule catches its seeded hazard BY NAME and honors the
  ``# lint: disable=<rule>`` pragma; the repo's own ``src/`` tree lints
  clean;
- an undonated state tick is caught by the ``missing-donation`` HLO
  audit rule; the repo's compiled ticks and migration transforms audit
  clean;
- the sanitizers (`compile_budget`, `no_transfers`, `debug_nan_checks`)
  enforce what they claim, and the migration-chain sentinel proves two
  generations of ingest → repad → compact → tick run with ZERO
  compiles outside explicit warming;
- the VMEM checker derives every kernel's footprint from its real
  BlockSpecs and validates it against the shared dispatch budget;
- grace-table retention: `ServiceConfig.grace_generations` bounds the
  generation-keyed remap table, and a lapsed delta raises
  `GraceLapseError` by name (live and restored services alike).
"""
import textwrap
from pathlib import Path

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.analysis.hlo_audit import (
    _audit_text,
    audit_migrations,
    audit_plan_tick,
)
from repro.analysis.lint import RULES, lint_paths, lint_source, lint_tree
from repro.analysis.sanitize import (
    CompileBudgetExceeded,
    TransferBudgetExceeded,
    assert_compiles_at_most,
    compile_budget,
    debug_nan_checks,
    no_transfers,
    transfer_budget,
)
from repro.analysis.vmem import (
    CapturedLaunch,
    collect_footprints,
    launch_footprint,
)
from repro.graphs.generators import erdos_renyi
from repro.graphs.layout import NodeLayout
from repro.graphs.types import GraphDelta
from repro.serving import (
    CheckpointPolicy,
    FingerService,
    GraceLapseError,
    IngestError,
    ServiceConfig,
    ServiceConfigError,
    TopKSpec,
)
from repro.serving.config import TopKSpec as _TopKSpec  # noqa: F401

SRC_ROOT = Path(__file__).resolve().parents[1] / "src"


def _rules(violations):
    return [v.rule for v in violations]


class TestLintRules:
    """Each seeded hazard is caught by its named rule."""

    def test_rule_registry_is_complete(self):
        assert set(RULES) == {
            "jit-static-unhashable", "traced-python-branch",
            "numpy-handoff-no-copy", "frozen-dataclass-mutable-default",
            "kernel-package-triple", "per-item-host-sync"}

    def test_jit_static_unhashable_mutable_default(self):
        src = textwrap.dedent("""
            import functools
            import jax

            @functools.partial(jax.jit, static_argnames=("opts",))
            def f(x, opts=[]):
                return x
        """)
        vs = lint_source(src, "seed.py")
        assert _rules(vs) == ["jit-static-unhashable"]
        assert "opts" in vs[0].message

    def test_jit_static_unhashable_unknown_param(self):
        src = textwrap.dedent("""
            import functools
            import jax

            @functools.partial(jax.jit, static_argnames=("missing",))
            def f(x):
                return x
        """)
        assert _rules(lint_source(src, "seed.py")) == \
            ["jit-static-unhashable"]

    def test_traced_python_branch(self):
        src = textwrap.dedent("""
            import jax

            @jax.jit
            def f(x):
                if x > 0:
                    return x
                return -x
        """)
        vs = lint_source(src, "seed.py")
        assert _rules(vs) == ["traced-python-branch"]

    def test_traced_branch_spares_static_args(self):
        src = textwrap.dedent("""
            import functools
            import jax

            @functools.partial(jax.jit, static_argnames=("mode",))
            def f(x, mode="a"):
                if mode == "b":
                    return -x
                return x
        """)
        assert lint_source(src, "seed.py") == []

    def test_numpy_handoff_no_copy(self):
        src = textwrap.dedent("""
            import numpy as np
            import jax.numpy as jnp

            def f():
                buf = np.zeros(4)
                arr = jnp.asarray(buf)
                buf[0] = 1.0
                return arr
        """)
        vs = lint_source(src, "seed.py")
        assert _rules(vs) == ["numpy-handoff-no-copy"]
        assert "buf" in vs[0].message

    def test_numpy_handoff_rebind_is_clean(self):
        # the buffer is rebound to a fresh copy each iteration before
        # the handoff: the handed-off array is never mutated afterwards
        # (the `graphs.streams` pattern the rule must not flag)
        src = textwrap.dedent("""
            import numpy as np
            import jax.numpy as jnp

            def f(w):
                out = []
                for _ in range(3):
                    w_new = w.copy()
                    w_new[0] = 1.0
                    out.append(jnp.asarray(w_new))
                return out
        """)
        vs = lint_source(src, "seed.py")
        assert "numpy-handoff-no-copy" not in _rules(
            [v for v in vs if not v.suppressed])

    def test_frozen_dataclass_mutable_default(self):
        src = textwrap.dedent("""
            import dataclasses

            @dataclasses.dataclass(frozen=True)
            class Config:
                xs: list = []
        """)
        assert _rules(lint_source(src, "seed.py")) == \
            ["frozen-dataclass-mutable-default"]

    def test_pragma_suppresses_by_name_and_all(self):
        src = textwrap.dedent("""
            import dataclasses

            @dataclasses.dataclass(frozen=True)
            class Config:
                xs: list = []  # lint: disable=frozen-dataclass-mutable-default
                ys: dict = {}  # lint: disable=all
        """)
        vs = lint_source(src, "seed.py")
        assert len(vs) == 2 and all(v.suppressed for v in vs)

    def test_kernel_triple_rule(self, tmp_path):
        pkg = tmp_path / "repro" / "kernels" / "newkernel"
        pkg.mkdir(parents=True)
        (pkg / "ops.py").write_text("x = 1\n")
        (pkg / "kernel.py").write_text("x = 1\n")
        report = lint_paths([], src_root=tmp_path)
        missing = sorted(v.message.split(" is missing ")[1].split(" ")[0]
                         for v in report.violations)
        assert _rules(report.violations) == ["kernel-package-triple"] * 2
        assert missing == ["parity.py", "ref.py"]

    def test_per_item_host_sync_seeded_hazards(self):
        # the PR-9 fleet hot-path class: per-slot host pulls in a loop
        src = textwrap.dedent("""
            import numpy as np

            def f(svc, slots):
                out = []
                for s in slots:
                    out.append(float(svc.score_at(s)))
                    out.append(np.asarray(svc.scores()))
                    out.append(svc.scores()[s].item())
                return out
        """)
        vs = lint_source(src, "seed.py")
        assert _rules(vs) == ["per-item-host-sync"] * 3
        assert any(".item()" in v.message for v in vs)

    def test_per_item_host_sync_spares_batched_pull(self):
        # the fixed form: one stacked pull, host-side indexing — and
        # float(Name)/float(sub[i]) reads of an already-host value
        src = textwrap.dedent("""
            import numpy as np

            def f(svc, slots):
                mat = svc.scores()
                host = np.asarray(mat)
                out = []
                for s in slots:
                    out.append(float(host[s]))
                return out
        """)
        assert lint_source(src, "seed.py") == []

    def test_per_item_host_sync_pragma(self):
        src = textwrap.dedent("""
            import numpy as np

            def f(rows):
                for r in rows:
                    yield np.asarray(r.strengths)  # lint: disable=per-item-host-sync
        """)
        vs = lint_source(src, "seed.py")
        assert _rules(vs) == ["per-item-host-sync"]
        assert vs[0].suppressed

    def test_repo_src_tree_lints_clean(self):
        report = lint_tree(SRC_ROOT)
        assert report.unsuppressed == [], \
            "\n".join(str(v) for v in report.unsuppressed)


class TestSanitizers:
    def test_compile_budget_counts_compiles(self):
        @jax.jit
        def f(x):
            return x * 2 + 1

        with compile_budget(None, "count-only") as c:
            f(jnp.zeros((5,)))
        assert c.count >= 1
        # cached call: zero compiles
        with compile_budget(0, "cached call") as c2:
            f(jnp.zeros((5,)))
        assert c2.count == 0

    def test_compile_budget_raises_by_name(self):
        @jax.jit
        def g(x):
            return x - 3

        with pytest.raises(CompileBudgetExceeded, match="seeded"):
            with compile_budget(0, "seeded recompile"):
                g(jnp.zeros((7,)))

    def test_assert_compiles_at_most(self):
        @jax.jit
        def h(x):
            return x + 5

        out = assert_compiles_at_most(h, 1, jnp.ones((3,)),
                                      what="first call")
        np.testing.assert_allclose(np.asarray(out), 6.0)
        with pytest.raises(CompileBudgetExceeded):
            assert_compiles_at_most(h, 0, jnp.ones((4, 4)),
                                    what="fresh shape")

    def test_no_transfers_blocks_implicit_scalar_transfer(self):
        # on the CPU backend only implicit scalar conversions cross the
        # guard (array views share the host buffer); on TPU any
        # device_get/put trips it
        x = jnp.arange(8)
        jax.block_until_ready(x)
        with pytest.raises(Exception, match="[Dd]isallow"):
            with no_transfers():
                float(x[0])

    def test_transfer_budget_counts_materializations(self):
        x = jnp.arange(16.0) * 2
        jax.block_until_ready(x)
        with transfer_budget(None, "count-only") as t:
            a = jax.device_get(x)
            b = jax.device_get(x)  # cached re-read: free
        assert t.count == 1
        assert a[3] == b[3] == 6.0
        # already-materialized arrays stay free in a later block
        with transfer_budget(0, "cached"):
            jax.device_get(x)

    def test_transfer_budget_raises_by_name(self):
        ys = [jnp.full((4,), float(i)) for i in range(3)]
        jax.block_until_ready(ys)
        with pytest.raises(TransferBudgetExceeded, match="per-slot"):
            with transfer_budget(1, "per-slot seeded"):
                for y in ys:
                    jax.device_get(y)  # lint: disable=per-item-host-sync

    def test_transfer_budget_restores_and_nests(self):
        from jax._src import array as _array_mod

        before = _array_mod.ArrayImpl._value
        with transfer_budget(None, "outer") as outer:
            with transfer_budget(None, "inner") as inner:
                jax.device_get(jnp.ones((3,)) + 1)
            assert _array_mod.ArrayImpl._value is not before
        assert _array_mod.ArrayImpl._value is before
        assert inner.count == 1
        assert outer.count == 1  # both blocks saw the one pull

    def test_debug_nan_checks_catches_nan(self):
        with pytest.raises(FloatingPointError):
            with debug_nan_checks():
                jax.block_until_ready(
                    jnp.divide(jnp.zeros(()), jnp.zeros(())))


class TestHloAudit:
    def _state(self):
        return {"a": jnp.zeros((8,)), "b": jnp.zeros((8,))}

    def test_missing_donation_caught_by_name(self):
        def tickish(state, x):
            return jax.tree_util.tree_map(lambda s: s + x, state)

        text = jax.jit(tickish).lower(self._state(), 1.0) \
            .compile().as_text()
        audit = _audit_text("undonated-tick", None, text,
                            n_state_leaves=2, require_donation=True)
        assert _rules(audit.violations) == ["missing-donation",
                                            "missing-donation"] or \
            _rules(audit.violations) == ["missing-donation"]
        assert "donate_argnums" in audit.violations[0].message

    def test_donated_tick_passes(self):
        def tickish(state, x):
            return jax.tree_util.tree_map(lambda s: s + x, state)

        text = jax.jit(tickish, donate_argnums=(0,)) \
            .lower(self._state(), 1.0).compile().as_text()
        audit = _audit_text("donated-tick", None, text,
                            n_state_leaves=2, require_donation=True)
        assert audit.ok
        assert audit.donated_params == [0, 1]

    def test_local_tick_audits_clean(self):
        config = ServiceConfig(batch_size=4, n_pad=16, k_pad=3,
                               placement="local", topk=TopKSpec(k=2))
        audit = audit_plan_tick(config)
        assert audit.ok, [v.message for v in audit.violations]
        # all five FingerState leaves donated
        assert audit.donated_params == [0, 1, 2, 3, 4]
        assert audit.host_transfers == []

    def test_migration_transforms_audit_clean(self):
        audits = audit_migrations(n_pad=16, batch_size=4)
        assert [a.target for a in audits] == \
            ["migrate.grow", "migrate.compact", "migrate.truncate",
             "migrate.grow_sparse"]
        for a in audits:
            assert a.ok, (a.target, [v.message for v in a.violations])


class TestVmemChecker:
    def test_every_kernel_validated_and_within_real_budget(self):
        from repro.kernels import dispatch
        from repro.kernels.parity import discover_kernel_packages

        # one capture run, driven with a deliberately tiny budget so
        # the over-budget path is exercised on real launches; the real
        # budget is then checked against the same derived footprints
        report = collect_footprints(budget_bytes=1000)
        packages = {f.package for f in report.footprints}
        assert packages == set(discover_kernel_packages())
        assert [v for v in report.violations
                if v.rule == "vmem-no-launch"] == []
        assert [v for v in report.violations
                if v.rule == "vmem-estimate-undercounts"] == []
        over = [v for v in report.violations
                if v.rule == "vmem-over-budget"]
        assert len(over) == len(report.footprints), \
            "every real launch exceeds a 1000-byte budget"
        budget = dispatch.vmem_budget_bytes()
        for fp in report.footprints:
            assert fp.step_bytes <= budget, \
                (fp.package, fp.kernel_name, fp.step_bytes)

    def test_launch_footprint_math(self):
        class _Spec:
            block_shape = (None, 128)

        class _Out:
            shape = (8, 128)
            dtype = np.float32

        launch = CapturedLaunch(
            kernel_name="k", module="repro.kernels.fake.kernel",
            grid=(4,), in_specs=[_Spec()], out_specs=[_Spec()],
            out_shape=[_Out()], scratch_shapes=None,
            operand_shapes=[(8, 512)], operand_dtypes=[np.float32])
        fp = launch_footprint(launch)
        assert fp.package == "fake"
        assert fp.in_bytes == 8 * 128 * 4   # None dim -> operand dim
        assert fp.out_bytes == 8 * 128 * 4
        assert fp.step_bytes == 2 * 8 * 128 * 4


class TestMigrationChainSentinel:
    def test_two_generations_zero_compiles(self):
        """The compile-count regression: ingest → repad → compact →
        tick across two migration generations, zero compiles in the
        serving phases (all compilation in explicit warming)."""
        from repro.analysis.sentinel import run_migration_chain

        result = run_migration_chain(ticks_per_phase=2)
        assert result["ok"]
        assert result["generations"] == 2
        assert result["phases"] == {"ticks_repad_gen0_to_1": 0,
                                    "ticks_compact_gen1_to_2": 0}


def _grace_graphs(b, n, seed=0):
    return [erdos_renyi(n, 0.4, seed=seed + s, weighted=True)
            for s in range(b)]


def _stamped_delta(graphs, layout, k_pad):
    return [GraphDelta.from_arrays(
        [0], [1], [0.5], [float(np.asarray(g.weights)[0, 1])],
        n_nodes=g.n_nodes, n_pad=layout.n_pad, k_pad=k_pad,
        layout=layout) for g in graphs]


class TestGraceRetention:
    def test_config_rejects_negative_grace(self):
        with pytest.raises(ServiceConfigError, match="grace_generations"):
            ServiceConfig(batch_size=2, n_pad=8, k_pad=2,
                          grace_generations=-1).validate()

    def test_prune_helper(self):
        from repro.serving import migrate

        table = {g: np.arange(4, dtype=np.int32) for g in range(5)}
        kept = migrate.prune_generation_remaps(table, 5, 2)
        assert sorted(kept) == [3, 4]
        assert sorted(migrate.prune_generation_remaps(table, 5, None)) \
            == [0, 1, 2, 3, 4]
        assert migrate.prune_generation_remaps(table, 5, 0) == {}

    def test_lapsed_generation_raises_by_name(self):
        b, k_pad = 2, 2
        graphs = _grace_graphs(b, 6, seed=11)
        cfg = ServiceConfig(batch_size=b, n_pad=8, k_pad=k_pad,
                            placement="local", ingestion="sync",
                            topk=TopKSpec(k=2), grace_generations=1)
        with FingerService.open(cfg, graphs) as svc:
            layouts = [svc.layout]
            for target in (16, 32):
                svc.repad(target)
                layouts.append(svc.layout)
            assert svc.layout.generation == 2
            assert sorted(svc._remaps_gen) == [1]
            # freshest retired generation still remaps
            svc.ingest(_stamped_delta(graphs, layouts[1], k_pad))
            assert svc.poll() is not None
            # pruned generation 0 raises the named lapse error
            with pytest.raises(GraceLapseError, match="grace"):
                svc.ingest(_stamped_delta(graphs, layouts[0], k_pad))
            # a future generation is a mis-stamp, not a lapse
            bogus = NodeLayout(32, generation=9)
            with pytest.raises(IngestError, match="generation 9"):
                svc.ingest(_stamped_delta(graphs, bogus, k_pad))

    def test_none_retains_every_generation(self):
        b, k_pad = 2, 2
        graphs = _grace_graphs(b, 6, seed=13)
        cfg = ServiceConfig(batch_size=b, n_pad=8, k_pad=k_pad,
                            placement="local", ingestion="sync",
                            topk=TopKSpec(k=2), grace_generations=None)
        with FingerService.open(cfg, graphs) as svc:
            gen0 = svc.layout
            for target in (16, 32, 64):
                svc.repad(target)
            assert sorted(svc._remaps_gen) == [0, 1, 2]
            svc.ingest(_stamped_delta(graphs, gen0, k_pad))
            assert svc.poll() is not None

    def test_restore_applies_retention(self, tmp_path):
        b, k_pad = 2, 2
        graphs = _grace_graphs(b, 6, seed=17)
        cfg = ServiceConfig(
            batch_size=b, n_pad=8, k_pad=k_pad, placement="local",
            ingestion="sync", topk=TopKSpec(k=2), grace_generations=1,
            checkpoint=CheckpointPolicy(str(tmp_path)))
        svc = FingerService.open(cfg, graphs)
        gen0 = svc.layout
        svc.repad(16)
        svc.repad(32)
        svc.save()
        cfg_now = svc.config
        svc.close()

        svc2 = FingerService.restore(cfg_now, directory=str(tmp_path))
        assert svc2.layout.generation == 2
        assert sorted(svc2._remaps_gen) == [1]
        with pytest.raises(GraceLapseError, match="grace"):
            svc2.ingest(_stamped_delta(graphs, gen0, k_pad))
        svc2.close()


class TestCli:
    def test_lint_subcommand_json(self, capsys):
        import json

        from repro.analysis.__main__ import main

        rc = main(["lint", "--json"])
        out = json.loads(capsys.readouterr().out)
        assert rc == 0
        assert out["ok"] is True
        assert out["checks"]["lint"]["ok"] is True
