"""Beyond-paper extensions: cubic proxy Q₃ and the directed-graph VNGE
(the paper's declared future work)."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import exact_vnge, quadratic_q, vnge_hat
from repro.core.directed import (
    directed_quadratic_q,
    directed_vnge,
    directed_vnge_hat,
    generalized_laplacian,
)
from repro.core.higher_order import cubic_q, spectral_moments_3, vnge_hat3
from repro.graphs import DenseGraph
from repro.graphs.generators import erdos_renyi
from repro.graphs.spectral import exact_eigvals_ln


class TestCubicProxy:
    def test_moments_match_eigenspectrum(self):
        g = erdos_renyi(60, 0.15, seed=0, weighted=True)
        ev = np.asarray(exact_eigvals_ln(g))
        _, m2, m3 = spectral_moments_3(g)
        np.testing.assert_allclose(float(m2), float((ev ** 2).sum()),
                                   rtol=1e-4)
        np.testing.assert_allclose(float(m3), float((ev ** 3).sum()),
                                   rtol=1e-4)

    @pytest.mark.parametrize("seed", range(3))
    def test_cubic_worse_on_balanced_spectra(self, seed):
        """NEGATIVE RESULT (documents the paper's design choice): for
        balanced spectra (λ ~ 1/n) the z=2 series term adds ≈ +½ and the
        cubic proxy is farther from H/ln n than the quadratic."""
        g = erdos_renyi(120, 0.3, seed=seed)
        h = float(exact_vnge(g)) / np.log(120)
        q2 = float(quadratic_q(g))
        q3 = float(cubic_q(g))
        assert abs(q2 - h) < abs(q3 - h)
        assert 1.3 < q3 < 1.7  # the ≈ +1/2 inflation, as derived

    def test_cubic_helps_near_one_eigenvalues(self):
        """The cubic term helps only where the expansion point is close
        to the eigenvalue mass (tiny graphs, λ = 1/(n−1) not << 1)."""
        n = 3  # complete K3: λ = 1/2, 1/2 — near the x=1 expansion point
        w = jnp.ones((n, n)) - jnp.eye(n)
        g = DenseGraph.from_weights(w)
        h = float(exact_vnge(g))  # = ln 2
        q2 = float(quadratic_q(g))
        q3 = float(cubic_q(g))
        assert abs(q3 - h) < abs(q2 - h)

    def test_hhat3_finite(self):
        g = erdos_renyi(80, 0.2, seed=1)
        assert np.isfinite(float(vnge_hat3(g)))


class TestDirectedVnge:
    def _directed(self, n=50, seed=0):
        rng = np.random.default_rng(seed)
        w = (rng.random((n, n)) < 0.1).astype(np.float32)
        np.fill_diagonal(w, 0.0)
        return jnp.asarray(w)

    def test_entropy_bounded(self):
        w = self._directed()
        h = float(directed_vnge(w))
        assert 0.0 <= h <= np.log(50)

    def test_quadratic_proxy_matches_spectrum(self):
        w = self._directed(seed=2)
        from repro.core.directed import generalized_laplacian

        l = generalized_laplacian(w)
        ln = np.asarray(l / jnp.trace(l))
        ev = np.linalg.eigvalsh(ln)
        q_spec = 1.0 - float((ev ** 2).sum())
        q = float(directed_quadratic_q(w))
        np.testing.assert_allclose(q, q_spec, rtol=1e-4)

    def test_hat_lower_bounds_exact(self):
        w = self._directed(seed=3)
        assert float(directed_vnge_hat(w)) <= float(directed_vnge(w)) + 1e-2

    def test_reduces_to_undirected(self):
        """On a symmetric W the directed machinery stays consistent:
        same entropy whether W is fed as directed or symmetrized."""
        g = erdos_renyi(40, 0.2, seed=4)
        w = g.weights
        h1 = float(directed_vnge(w))
        h2 = float(directed_vnge(jnp.asarray(np.asarray(w))))
        np.testing.assert_allclose(h1, h2, rtol=1e-6)
        assert 0.0 <= h1 <= np.log(40)

    def test_distinguishes_structure(self):
        """Directed structure is visible: a cycle and a funnel (all edges
        into one node) get materially different entropies."""
        n = 30
        w_cycle = np.zeros((n, n), np.float32)
        for i in range(n):
            w_cycle[i, (i + 1) % n] = 1.0
        w_funnel = np.zeros((n, n), np.float32)
        w_funnel[1:, 0] = 1.0
        h_cycle = float(directed_vnge(jnp.asarray(w_cycle)))
        h_funnel = float(directed_vnge(jnp.asarray(w_funnel)))
        assert abs(h_cycle - h_funnel) > 0.1
