"""Fused batched serving tick (`kernels.stream_tick`) vs the vmapped
reference, in interpret mode on CPU.

Acceptance anchors (ISSUE 5):
- interpret-mode parity to 1e-5 against the vmapped Algorithm-2 tick
  across mixed-n batches, join/leave node slots, graph-emptying and
  reviving deltas, and empty (all-masked) ticks (property tests);
- the fused tick compiles ONCE across mixed-n batches (jit-cache
  assertion on the `StreamEngine(method="fused_tick")` tick);
- the VMEM size guard routes oversized tiles to the vmapped path with
  identical numerics, and `method="fused_tick"` flows through
  `update_state`/`jsdist_incremental`/`ServiceConfig` end to end.
"""
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.analysis.sanitize import compile_budget
from repro.core import finger_state, jsdist_incremental, update_state
from repro.engine import StreamEngine, stack_deltas
from repro.graphs import DenseGraph, GraphDelta
from repro.graphs.generators import erdos_renyi
from repro.kernels.stream_tick import ops as stops
from repro.kernels.stream_tick.ops import (
    fits_fused_tick,
    stream_tick_fused,
)
from repro.kernels.stream_tick.ref import stream_tick_ref


def _assert_tick_matches(states, stacked, exact_smax, atol=1e-5,
                         label=""):
    d_ref, s_ref = stream_tick_ref(states, stacked,
                                   exact_smax=exact_smax)
    d_f, s_f = stream_tick_fused(states, stacked,
                                 exact_smax=exact_smax)
    np.testing.assert_allclose(np.asarray(d_f), np.asarray(d_ref),
                               atol=atol, err_msg=f"{label}: dist")
    for field in ("q", "s_total", "s_max", "strengths", "node_mask"):
        np.testing.assert_allclose(
            np.asarray(getattr(s_f, field)),
            np.asarray(getattr(s_ref, field)),
            atol=atol, err_msg=f"{label}: {field}")
    return s_f


class _Stream:
    """One tenant over its own node universe, emitting identical deltas
    to the fused engine and the per-stream unpadded oracle."""

    def __init__(self, n0, n_reserve, seed):
        self.n_total = n0 + n_reserve
        rng = np.random.default_rng(seed)
        w = np.zeros((self.n_total, self.n_total), np.float32)
        upper = np.triu(rng.random((n0, n0)) < 0.3, k=1)
        w[:n0, :n0] = upper * rng.uniform(0.5, 1.5, (n0, n0))
        w[:n0, :n0] += w[:n0, :n0].T
        self.w = w
        self.active = list(range(n0))
        self.reserve = list(range(n0, self.n_total))
        self.joined = []

    def random_tick(self, rng, k, k_pad, j_pad, n_pad):
        join, leave, ii, jj = [], [], [], []
        if self.reserve and rng.random() < 0.4:
            v = self.reserve.pop(0)
            join.append(v)
            self.joined.append(v)
            self.active.append(v)
            for u in rng.choice(
                    [a for a in self.active if a != v],
                    size=min(2, len(self.active) - 1), replace=False):
                ii.append(min(v, int(u)))
                jj.append(max(v, int(u)))
        elif self.joined and rng.random() < 0.4:
            v = self.joined.pop(0)
            leave.append(v)
            self.active.remove(v)
            for u in np.flatnonzero(self.w[v]):
                ii.append(min(v, int(u)))
                jj.append(max(v, int(u)))
        pairs = {(a, b) for a, b in zip(ii, jj)}
        while len(pairs) < k and len(self.active) >= 2:
            a, b = rng.choice(self.active, size=2, replace=False)
            a, b = min(int(a), int(b)), max(int(a), int(b))
            if a != b:
                pairs.add((a, b))
        ii = np.array([p[0] for p in pairs], np.int32)
        jj = np.array([p[1] for p in pairs], np.int32)
        w_old = self.w[ii, jj]
        dw = np.where(
            np.isin(ii, leave) | np.isin(jj, leave) | (w_old > 0),
            -w_old, rng.uniform(0.2, 1.5, len(ii)).astype(np.float32))
        dw = dw.astype(np.float32)
        keep = np.abs(dw) > 1e-12
        ii, jj, dw, w_old = ii[keep], jj[keep], dw[keep], w_old[keep]
        self.w[ii, jj] += dw
        self.w[jj, ii] += dw
        return GraphDelta.from_arrays(
            ii, jj, dw, w_old, n_nodes=self.n_total, n_pad=n_pad,
            k_pad=k_pad, join=join, leave=leave, j_pad=j_pad)

    def engine_graph(self, n_pad):
        n0 = len(self.active)
        return DenseGraph.from_weights(
            jnp.asarray(self.w[:n0, :n0]), n_pad=n_pad)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10_000), exact=st.booleans())
def test_property_fused_matches_reference_mixed_n_join_leave(seed, exact):
    """Ticks over a heterogeneous batch with joins/leaves: the fused
    kernel must match the vmapped reference to 1e-5 every tick."""
    rng = np.random.default_rng(seed)
    n_pad, k_pad, j_pad, ticks = 40, 8, 2, 4
    streams = [_Stream(n0=int(rng.integers(5, 24)), n_reserve=3,
                       seed=seed * 11 + i) for i in range(4)]
    states = StreamEngine.init_states(
        [s.engine_graph(n_pad) for s in streams], n_pad=n_pad)
    for t in range(ticks):
        stacked = stack_deltas([
            s.random_tick(rng, k=4, k_pad=k_pad, j_pad=j_pad,
                          n_pad=n_pad) for s in streams])
        states = _assert_tick_matches(states, stacked, exact,
                                      label=f"tick {t}")


class TestEdgeCases:
    def _dead_live_states(self):
        dead = DenseGraph.from_weights(
            jnp.zeros((4, 4)), n_pad=16,
            node_mask=np.zeros(4, np.float32))
        live = erdos_renyi(12, 0.3, seed=0, weighted=True)
        return StreamEngine.init_states([dead, live], n_pad=16)

    def test_empty_delta_tick(self):
        states = self._dead_live_states()
        empty = GraphDelta.from_arrays([], [], [], [], n_nodes=16,
                                       k_pad=4, j_pad=2)
        out = _assert_tick_matches(states,
                                   stack_deltas([empty, empty]),
                                   exact_smax=True, label="empty")
        # the dead stream keeps emitting finite zero scores
        d, _ = stream_tick_fused(states, stack_deltas([empty, empty]))
        assert float(d[0]) == 0.0
        assert np.isfinite(np.asarray(d)).all()
        assert float(out.q[0]) == 1.0

    def test_graph_emptying_then_reviving(self):
        """Deleting every edge snaps to the canonical empty state; a
        join + first-edges delta revives it — both matching the
        reference exactly."""
        states = self._dead_live_states()
        live = erdos_renyi(12, 0.3, seed=0, weighted=True)
        w = np.asarray(live.weights)
        iu, ju = np.nonzero(np.triu(w, 1))
        kill = GraphDelta.from_arrays(
            iu, ju, -w[iu, ju], w[iu, ju], n_nodes=12, n_pad=16,
            k_pad=64, j_pad=2)
        empty = GraphDelta.from_arrays([], [], [], [], n_nodes=16,
                                       k_pad=64, j_pad=2)
        after = _assert_tick_matches(states,
                                     stack_deltas([empty, kill]),
                                     exact_smax=True, label="emptying")
        assert float(after.s_total[1]) == 0.0
        assert float(after.q[1]) == 1.0
        revive = GraphDelta.from_arrays(
            [0], [1], [2.0], [0.0], n_nodes=16, k_pad=4,
            join=[0, 1], j_pad=2)
        empty4 = GraphDelta.from_arrays([], [], [], [], n_nodes=16,
                                        k_pad=4, j_pad=2)
        out = _assert_tick_matches(after,
                                   stack_deltas([revive, empty4]),
                                   exact_smax=True, label="revive")
        # revive-from-empty is exact: c' = 1/ΔS, so H̃ matches a fresh
        # two-node graph bit-for-bit up to f32
        ref = finger_state(DenseGraph.from_weights(
            2.0 * jnp.eye(2)[::-1], n_pad=16))
        got = jax.tree_util.tree_map(lambda x: x[0], out)
        assert abs(float(got.h_tilde()) - float(ref.h_tilde())) < 1e-6

    def test_stray_edges_into_padding_are_gated(self):
        """Delta edges pointing at inactive node slots contribute
        exactly zero — the in-kernel gate matches `update_state`'s."""
        g = erdos_renyi(30, 0.2, seed=2, weighted=True).pad_to(48)
        states = StreamEngine.init_states([g.pad_to(48)], n_pad=48)
        stray = GraphDelta.from_arrays(
            [0, 2, 40], [5, 9, 45], [0.5, -0.1, 9.9], [0.0, 0.3, 0.0],
            n_nodes=48, k_pad=4)
        clean = GraphDelta.from_arrays(
            [0, 2], [5, 9], [0.5, -0.1], [0.0, 0.3], n_nodes=48,
            k_pad=4)
        d_s, st_s = stream_tick_fused(states, stack_deltas([stray]))
        d_c, st_c = stream_tick_fused(states, stack_deltas([clean]))
        assert abs(float(d_s[0]) - float(d_c[0])) < 1e-6
        assert abs(float(st_s.q[0]) - float(st_c.q[0])) < 1e-6

    def test_duplicate_edge_slots_share_a_segment(self):
        """The same (i, j) pair in two delta slots must sum into one
        node segment, exactly as the reference's segment sum does."""
        g = erdos_renyi(10, 0.4, seed=4, weighted=True)
        states = StreamEngine.init_states([g], n_pad=16)
        w01 = float(np.asarray(g.weights)[0, 1])
        dup = GraphDelta.from_arrays(
            [0, 0], [1, 1], [0.3, 0.2], [w01, w01 + 0.3], n_nodes=10,
            n_pad=16, k_pad=4)
        _assert_tick_matches(states, stack_deltas([dup]),
                             exact_smax=True, label="dup-edge")


class TestDispatch:
    def test_vmem_guard_routes_oversized_tiles_to_reference(self):
        assert fits_fused_tick(128, 16, 2)
        assert not fits_fused_tick(128, 4096, 2)  # endpoint ceiling
        assert not fits_fused_tick(200_000, 16, 2)  # one-hot blowup
        g = erdos_renyi(12, 0.3, seed=0, weighted=True)
        states = StreamEngine.init_states([g], n_pad=12)
        d = GraphDelta.from_arrays(
            [0], [1], [0.4], [float(np.asarray(g.weights)[0, 1])],
            n_nodes=12, k_pad=4096)  # > MAX_ENDPOINTS after padding
        d_f, _ = stream_tick_fused(states, stack_deltas([d]))
        d_r, _ = stream_tick_ref(states, stack_deltas([d]))
        np.testing.assert_allclose(np.asarray(d_f), np.asarray(d_r),
                                   atol=1e-6)

    def test_maskless_state_falls_back(self):
        """A legacy mask-less stacked state routes to the vmapped path
        (the kernel's gating needs the mask in the carried state)."""
        graphs = [erdos_renyi(8, 0.3, seed=s, weighted=True)
                  for s in range(2)]
        states = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs),
            *[finger_state(g) for g in graphs])
        assert states.node_mask is None
        d = stack_deltas([GraphDelta.from_arrays(
            [0], [1], [0.3], [float(np.asarray(g.weights)[0, 1])],
            n_nodes=8, k_pad=2) for g in graphs])
        d_f, _ = stream_tick_fused(states, d)
        d_r, _ = stream_tick_ref(states, d)
        np.testing.assert_allclose(np.asarray(d_f), np.asarray(d_r),
                                   atol=1e-6)

    def test_larger_layout_delta_rejected_at_trace_time(self):
        g = erdos_renyi(8, 0.3, seed=0, weighted=True)
        states = StreamEngine.init_states([g], n_pad=8)
        d = stack_deltas([GraphDelta.from_arrays(
            [0], [9], [0.3], [0.0], n_nodes=12, k_pad=2)])
        with pytest.raises(ValueError, match="migrate the state first"):
            stream_tick_fused(states, d)


class TestEngineWiring:
    def _mixed(self, b=6, n_pad=32, k_pad=4, seed=0):
        rng = np.random.default_rng(seed)
        ns = [int(n) for n in np.linspace(8, n_pad, b).astype(int)]
        graphs = [erdos_renyi(n, 0.2, seed=s, weighted=True)
                  for s, n in enumerate(ns)]
        states = StreamEngine.init_states(graphs, n_pad=n_pad)

        def mk():
            ds = []
            for g in graphs:
                n = g.n_nodes
                i = int(rng.integers(0, n - 1))
                w_old = float(np.asarray(g.weights)[i, i + 1])
                ds.append(GraphDelta.from_arrays(
                    [i], [i + 1], [0.4 if w_old == 0 else -w_old],
                    [w_old], n_nodes=n, n_pad=n_pad, k_pad=k_pad))
            return stack_deltas(ds)

        return states, mk

    def test_fused_engine_compiles_once_across_mixed_n(self):
        """The jit-cache assertion: mixed-n batches (distinct masks,
        same shapes) reuse ONE compiled fused tick — the first tick
        compiles, the rest run under a zero-compile budget."""
        states, mk = self._mixed()
        engine = StreamEngine(method="fused_tick")
        dists, states = engine.tick(states, mk())
        with compile_budget(0, "fused tick across mixed-n batches"):
            for _ in range(2):
                dists, states = engine.tick(states, mk())
        assert np.isfinite(np.asarray(dists)).all()

    def test_fused_engine_matches_dense_engine(self):
        states_f, mk = self._mixed(seed=3)
        states_d = jax.tree_util.tree_map(jnp.copy, states_f)
        fused = StreamEngine(method="fused_tick")
        dense = StreamEngine(method="dense")
        for _ in range(3):
            d = mk()
            df, states_f = fused.tick(states_f, d)
            dd, states_d = dense.tick(states_d, d)
            np.testing.assert_allclose(np.asarray(df), np.asarray(dd),
                                       atol=1e-5)

    def test_fused_engine_run_scans_the_fused_body(self):
        states, mk = self._mixed(seed=5)
        seq = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *[mk() for _ in range(3)])
        fused = StreamEngine(method="fused_tick")
        dists, final = fused.run(states, seq)
        assert dists.shape == (3, 6)
        assert np.isfinite(np.asarray(dists)).all()

    def test_update_state_fused_tick_method(self):
        """`method="fused_tick"` on the per-stream entry points routes
        through the fused delta-stats kernel with identical numbers."""
        g = erdos_renyi(20, 0.2, seed=5, weighted=True).pad_to(32)
        state = finger_state(g)
        d = GraphDelta.from_arrays(
            [20, 20], [3, 7], [0.8, 0.6], [0.0, 0.0], n_nodes=32,
            k_pad=4, join=[20], j_pad=2)
        ref = update_state(state, d, exact_smax=True, method="dense")
        got = update_state(state, d, exact_smax=True,
                           method="fused_tick")
        for field in ("q", "s_total", "s_max"):
            assert abs(float(getattr(got, field))
                       - float(getattr(ref, field))) < 1e-5, field
        r_ref, _ = jsdist_incremental(state, d, method="dense")
        r_got, _ = jsdist_incremental(state, d, method="fused_tick")
        assert abs(float(r_got) - float(r_ref)) < 1e-5

    def test_unknown_method_still_raises(self):
        g = erdos_renyi(8, 0.3, seed=0, weighted=True)
        d = GraphDelta.from_arrays([0], [1], [0.2], [0.0], n_nodes=8)
        with pytest.raises(ValueError, match="unknown delta-stats"):
            update_state(finger_state(g), d, method="bogus")


class TestPreparation:
    def test_lane_alignment_and_vmem_estimate(self):
        assert stops._ceil_to(1, 128) == 128
        assert stops._ceil_to(128, 128) == 128
        assert stops._ceil_to(129, 128) == 256
        # the estimate is monotone in every tile dimension
        assert stops.fused_tick_vmem_bytes(256, 64, 2) \
            <= stops.fused_tick_vmem_bytes(512, 64, 2)
        assert stops.fused_tick_vmem_bytes(256, 64, 2) \
            <= stops.fused_tick_vmem_bytes(256, 256, 2)
