"""Offline-safe property-testing shim.

The container has no network access, so `hypothesis` may be absent. This
module exports `given` / `settings` / `strategies` with the subset of the
hypothesis API the suite uses. When the real library is importable we
re-export it unchanged (shrinking, the database, etc. all work); when it
is not, the shim degrades to *seeded deterministic sampling*: `given`
draws `max_examples` pseudo-random examples per test (seeded from the
test's qualified name, so failures reproduce run-to-run) and executes the
test body once per example — the same spirit as a
`pytest.mark.parametrize` over sampled inputs.

Usage (drop-in for the suite's import):

    from _propcheck import given, settings, strategies as st
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import zlib

    import numpy as np

    class _Strategy:
        """A value generator: `draw(rng)` returns one sample."""

        def __init__(self, draw_fn, label):
            self._draw = draw_fn
            self._label = label

        def draw(self, rng) -> object:
            return self._draw(rng)

        def __repr__(self):
            return f"<strategy {self._label}>"

    class strategies:  # noqa: N801 - mirrors the hypothesis module name
        """Subset of `hypothesis.strategies` used by this suite."""

        @staticmethod
        def integers(min_value: int, max_value: int) -> _Strategy:
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)),
                f"integers({min_value}, {max_value})",
            )

        @staticmethod
        def floats(min_value: float, max_value: float) -> _Strategy:
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)),
                f"floats({min_value}, {max_value})",
            )

        @staticmethod
        def booleans() -> _Strategy:
            return _Strategy(lambda rng: bool(rng.integers(0, 2)),
                             "booleans()")

        @staticmethod
        def sampled_from(options) -> _Strategy:
            options = list(options)
            return _Strategy(
                lambda rng: options[int(rng.integers(0, len(options)))],
                f"sampled_from({options!r})",
            )

    _DEFAULT_MAX_EXAMPLES = 10

    def given(**strategy_kwargs):
        """Run the test once per drawn example (seeded, deterministic)."""

        def decorate(fn):
            def runner(*args, **kwargs):
                n = getattr(runner, "_pc_max_examples",
                            _DEFAULT_MAX_EXAMPLES)
                seed = zlib.crc32(
                    f"{fn.__module__}.{fn.__qualname__}".encode())
                rng = np.random.default_rng(seed)
                for example in range(n):
                    drawn = {name: s.draw(rng)
                             for name, s in strategy_kwargs.items()}
                    try:
                        fn(*args, **drawn, **kwargs)
                    except Exception as e:  # surface the failing example
                        raise AssertionError(
                            f"{fn.__qualname__} failed on example "
                            f"{example + 1}/{n}: {drawn!r}") from e

            # Deliberately NOT functools.wraps: pytest must see the
            # zero-argument signature, or it would treat the strategy
            # parameters as missing fixtures.
            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            runner.__module__ = fn.__module__
            runner.__qualname__ = fn.__qualname__
            runner._pc_inner = fn
            return runner

        return decorate

    def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES,
                 deadline=None, **_ignored):
        """Attach the example budget to a `given`-wrapped test."""

        def decorate(fn):
            fn._pc_max_examples = max_examples
            return fn

        return decorate
