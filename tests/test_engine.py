"""Batched StreamEngine + compact/fused delta-stats: equivalence with
the serial dense paths, shard_map serving, and edge cases."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (
    delta_stats,
    delta_stats_compact,
    finger_state,
    jsdist_incremental,
    jsdist_stream,
    update_state,
)
from repro.engine import StreamEngine, stack_deltas, stack_states
from repro.graphs import GraphDelta, apply_delta_dense
from repro.graphs.generators import erdos_renyi
from repro.kernels.delta_stats.ops import delta_stats_fused


def _random_delta(g, rng, k=16, k_pad=None, delete_frac=0.4,
                  hit_argmax=False):
    """Random add/delete/re-weight delta; optionally delete at argmax."""
    n = g.n_nodes
    w = np.asarray(g.weights)
    pairs = {}
    if hit_argmax:
        amax = int(w.sum(1).argmax())
        nbrs = np.flatnonzero(w[amax])
        for j in nbrs[:3]:
            a, b = min(amax, int(j)), max(amax, int(j))
            pairs[(a, b)] = (-w[a, b], w[a, b])  # deletion at the argmax
    while len(pairs) < k:
        i, j = rng.integers(0, n, 2)
        if i == j:
            continue
        i, j = min(i, j), max(i, j)
        if (i, j) in pairs:
            continue
        w_old = w[i, j]
        if w_old > 0 and rng.random() < delete_frac:
            dw = -w_old
        else:
            dw = float(rng.uniform(0.1, 2.0))
        pairs[(i, j)] = (dw, w_old)
    ii = np.array([p[0] for p in pairs], np.int32)
    jj = np.array([p[1] for p in pairs], np.int32)
    dw = np.array([v[0] for v in pairs.values()], np.float32)
    wo = np.array([v[1] for v in pairs.values()], np.float32)
    return GraphDelta.from_arrays(ii, jj, dw, wo, n_nodes=n, k_pad=k_pad)


class TestCompactDeltaStats:
    @pytest.mark.parametrize("seed", range(6))
    @pytest.mark.parametrize("hit_argmax", [False, True])
    def test_compact_matches_dense(self, seed, hit_argmax):
        rng = np.random.default_rng(seed)
        g = erdos_renyi(90, 0.1, seed=seed, weighted=True)
        st = finger_state(g)
        d = _random_delta(g, rng, k=20, k_pad=32, hit_argmax=hit_argmax)
        ds_d, dq_d, _, mx_d = delta_stats(st, d)
        ds_c, dq_c, mx_c = delta_stats_compact(st, d)
        assert abs(float(ds_d) - float(ds_c)) < 1e-5
        np.testing.assert_allclose(float(dq_d), float(dq_c),
                                   rtol=1e-5, atol=1e-5)
        assert abs(float(mx_d) - float(mx_c)) < 1e-5

    @pytest.mark.parametrize("exact_smax", [False, True])
    def test_compact_update_chain_matches_recompute(self, exact_smax):
        """10 chained compact updates (incl. argmax deletions) track the
        from-scratch state."""
        rng = np.random.default_rng(11)
        g = erdos_renyi(70, 0.12, seed=11, weighted=True)
        st = finger_state(g)
        for step in range(10):
            d = _random_delta(g, rng, k=14, k_pad=32,
                              hit_argmax=step % 3 == 0)
            st = update_state(st, d, exact_smax=exact_smax,
                              method="compact")
            g = apply_delta_dense(g, d)
        ref = finger_state(g)
        assert abs(float(st.q) - float(ref.q)) < 1e-4
        assert abs(float(st.s_total) - float(ref.s_total)) < 1e-2
        np.testing.assert_allclose(np.asarray(st.strengths),
                                   np.asarray(ref.strengths), atol=1e-3)
        if exact_smax:
            assert abs(float(st.s_max) - float(ref.s_max)) < 1e-3
        else:  # eq. (3): never decreases
            assert float(st.s_max) >= float(ref.s_max) - 1e-4

    def test_compact_empty_delta(self):
        g = erdos_renyi(40, 0.2, seed=0, weighted=True)
        st = finger_state(g)
        d = GraphDelta.from_arrays([], [], [], [], n_nodes=40, k_pad=8)
        new = update_state(st, d, method="compact")
        assert abs(float(new.q) - float(st.q)) < 1e-6
        assert abs(float(new.h_tilde()) - float(st.h_tilde())) < 1e-6


class TestFusedKernel:
    @pytest.mark.parametrize("seed", range(4))
    @pytest.mark.parametrize("use_pallas", [False, True])
    def test_fused_matches_dense(self, seed, use_pallas):
        """Pallas (interpret on CPU) and ref oracle vs the dense path on
        randomized add/delete/re-weight deltas."""
        rng = np.random.default_rng(seed + 100)
        g = erdos_renyi(120, 0.08, seed=seed, weighted=True)
        st = finger_state(g)
        d = _random_delta(g, rng, k=30, k_pad=48,
                          hit_argmax=seed % 2 == 0)
        ds_d, dq_d, _, mx_d = delta_stats(st, d)
        ds_f, dq_f, mx_f = delta_stats_fused(st, d, use_pallas=use_pallas)
        assert abs(float(ds_d) - float(ds_f)) < 1e-5
        np.testing.assert_allclose(float(dq_d), float(dq_f),
                                   rtol=1e-5, atol=1e-5)
        assert abs(float(mx_d) - float(mx_f)) < 1e-5

    def test_fused_htilde_to_1e5(self):
        """End metric: H̃ after the update from fused stats matches the
        dense-path H̃ to ≤1e-5."""
        rng = np.random.default_rng(7)
        g = erdos_renyi(100, 0.1, seed=7, weighted=True)
        st = finger_state(g)
        d = _random_delta(g, rng, k=24, k_pad=32)
        dense_new = update_state(st, d, method="dense")
        compact_new = update_state(st, d, method="compact")
        assert abs(float(dense_new.h_tilde())
                   - float(compact_new.h_tilde())) < 1e-5

    def test_fused_empty_delta(self):
        g = erdos_renyi(64, 0.1, seed=1, weighted=True)
        st = finger_state(g)
        d = GraphDelta.from_arrays([], [], [], [], n_nodes=64, k_pad=4)
        for use_pallas in (False, True):
            ds, dq, mx = delta_stats_fused(st, d, use_pallas=use_pallas)
            assert float(ds) == 0.0 and float(dq) == 0.0
            assert np.isneginf(float(mx))


class TestStreamEngine:
    def _make_streams(self, b, n, k, t, seed=0):
        rng = np.random.default_rng(seed)
        graphs = [erdos_renyi(n, 0.1, seed=s, weighted=True)
                  for s in range(b)]
        gs = list(graphs)
        ticks = []
        for _ in range(t):
            ds = [_random_delta(g, rng, k=k, k_pad=k) for g in gs]
            gs = [apply_delta_dense(g, d) for g, d in zip(gs, ds)]
            ticks.append(stack_deltas(ds))
        seq = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *ticks)
        return graphs, seq

    @pytest.mark.parametrize("method", ["dense", "compact"])
    def test_engine_matches_per_stream_scan_b256(self, method):
        """Acceptance: B=256 engine sequences == per-stream jsdist_stream
        to ≤1e-5."""
        b, n, k, t = 256, 48, 8, 4
        graphs, seq = self._make_streams(b, n, k, t, seed=3)
        engine = StreamEngine(method=method)
        dists, final = engine.run(StreamEngine.init_states(graphs), seq)
        assert dists.shape == (t, b)
        for s in range(0, b, 37):  # spot-check streams across the batch
            per = jax.tree_util.tree_map(lambda x: x[:, s], seq)
            ref, _ = jsdist_stream(finger_state(graphs[s]), per)
            np.testing.assert_allclose(np.asarray(dists[:, s]),
                                       np.asarray(ref), atol=1e-5)

    def test_tick_matches_run(self):
        b, n, k, t = 16, 40, 6, 3
        graphs, seq = self._make_streams(b, n, k, t, seed=9)
        engine = StreamEngine()
        run_d, _ = engine.run(StreamEngine.init_states(graphs), seq)
        st = StreamEngine.init_states(graphs)
        for i in range(t):
            tick_d, st = engine.tick(
                st, jax.tree_util.tree_map(lambda x: x[i], seq))
            np.testing.assert_allclose(np.asarray(tick_d),
                                       np.asarray(run_d[i]), atol=1e-6)

    def test_engine_matches_incremental_loop(self):
        b, n, k = 8, 40, 6
        graphs, seq = self._make_streams(b, n, k, 1, seed=5)
        engine = StreamEngine(exact_smax=True)
        d0 = jax.tree_util.tree_map(lambda x: x[0], seq)
        dists, _ = engine.tick(StreamEngine.init_states(graphs), d0)
        for s in range(b):
            d = jax.tree_util.tree_map(lambda x: x[s], d0)
            ref, _ = jsdist_incremental(finger_state(graphs[s]), d,
                                        exact_smax=True)
            assert abs(float(dists[s]) - float(ref)) < 1e-6

    def test_stack_deltas_rejects_mixed_k_pad(self):
        d1 = GraphDelta.from_arrays([0], [1], [1.0], [0.0], n_nodes=4,
                                    k_pad=4)
        d2 = GraphDelta.from_arrays([0], [1], [1.0], [0.0], n_nodes=4,
                                    k_pad=8)
        with pytest.raises(ValueError, match="common k_pad"):
            stack_deltas([d1, d2])

    def test_stack_states_roundtrip(self):
        graphs = [erdos_renyi(30, 0.2, seed=s, weighted=True)
                  for s in range(4)]
        stacked = stack_states([finger_state(g) for g in graphs])
        assert stacked.q.shape == (4,)
        assert stacked.strengths.shape == (4, 30)


_SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.engine import StreamEngine, stack_deltas
from repro.graphs import GraphDelta
from repro.graphs.generators import erdos_renyi

b, n, k = 32, 40, 6
rng = np.random.default_rng(0)
graphs = [erdos_renyi(n, 0.1, seed=s, weighted=True) for s in range(b)]
deltas = []
for g in graphs:
    w = np.asarray(g.weights)
    iu, ju = np.triu_indices(n, k=1)
    pick = rng.choice(len(iu), size=k, replace=False)
    ii, jj = iu[pick], ju[pick]
    wo = w[ii, jj]
    dw = np.where(wo > 0, -wo, 1.0).astype(np.float32)
    deltas.append(GraphDelta.from_arrays(ii, jj, dw, wo, n_nodes=n, k_pad=k))
stacked = stack_deltas(deltas)

engine = StreamEngine()
local_d, _ = engine.tick(StreamEngine.init_states(graphs), stacked)

mesh = jax.make_mesh((8,), ("data",))
tick = engine.make_sharded_tick(mesh, "data")
st = engine.shard_states(StreamEngine.init_states(graphs), mesh, "data")
sharding = NamedSharding(mesh, P("data"))
stacked_sh = jax.tree_util.tree_map(
    lambda x: jax.device_put(x, sharding), stacked)
shard_d, _ = tick(st, stacked_sh)
print(json.dumps({
    "n_devices": jax.device_count(),
    "max_err": float(jnp.abs(shard_d - local_d).max()),
}))
"""


@pytest.mark.slow
def test_sharded_tick_matches_local():
    """shard_map serving over 8 placeholder devices == single-device."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    proc = subprocess.run([sys.executable, "-c", _SHARDED_SCRIPT],
                          env=env, capture_output=True, text=True,
                          timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["n_devices"] == 8
    assert out["max_err"] < 1e-6
